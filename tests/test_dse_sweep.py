"""Sweep orchestration + Pareto extraction, and the full-scale (slow)
batch-vs-scalar equivalence / speedup check from the acceptance criteria."""

import numpy as np
import pytest

from repro.core.cim import DEFAULT_ARRAY
from repro.dse import (
    SweepPoint,
    design_grid,
    pareto_frontier,
    pareto_mask,
    run_sweep,
)

FAST_KW = dict(profile_images=1, sample_patches=32)


# ------------------------------------------------------------------- pareto
def test_pareto_mask_basic():
    # maximize both: (2,2) dominates (1,1); (3,0)/(0,3) are corner points
    pts = np.array([[1.0, 1.0], [2.0, 2.0], [3.0, 0.0], [0.0, 3.0]])
    mask = pareto_mask(pts, [True, True])
    assert mask.tolist() == [False, True, True, True]


def test_pareto_mask_minimize_axis():
    # minimize first axis: (1, 5) beats (2, 5); (3, 7) survives on axis 2
    pts = np.array([[1.0, 5.0], [2.0, 5.0], [3.0, 7.0]])
    mask = pareto_mask(pts, [False, True])
    assert mask.tolist() == [True, False, True]


def test_pareto_mask_duplicates_kept():
    pts = np.array([[1.0, 1.0], [1.0, 1.0], [0.5, 0.5]])
    mask = pareto_mask(pts, [True, True])
    assert mask.tolist() == [True, True, False]


def test_pareto_mask_validates():
    with pytest.raises(ValueError, match="objectives"):
        pareto_mask(np.zeros(3), [True])
    with pytest.raises(ValueError, match="maximize"):
        pareto_mask(np.zeros((3, 2)), [True])


# -------------------------------------------------------------------- sweep
def test_design_grid_feasible_and_cartesian():
    arrays = (DEFAULT_ARRAY, DEFAULT_ARRAY.variant(rows=256, cols=256))
    pts = design_grid(
        networks=("vgg11",), pe_multipliers=(1.0, 2.0), arrays=arrays
    )
    assert len(pts) == 2 * 2 * 5  # arrays x multipliers x policies
    # every point is at least the minimum design size for ITS geometry
    from repro.core.cim import vgg11_cifar10, with_array

    for p in pts:
        spec = with_array(vgg11_cifar10(), p.array)
        assert p.n_pes >= spec.min_pes()


def test_run_sweep_batch_matches_scalar_small():
    pts = design_grid(networks=("vgg11",), pe_multipliers=(1.0, 1.7, 3.0))
    batch = run_sweep(pts, **FAST_KW)
    scalar = run_sweep(pts, engine="scalar", **FAST_KW)
    np.testing.assert_array_equal(batch.arrays_used, scalar.arrays_used)
    np.testing.assert_allclose(batch.total_cycles, scalar.total_cycles, rtol=1e-9)
    np.testing.assert_allclose(batch.images_per_sec, scalar.images_per_sec, rtol=1e-9)
    np.testing.assert_allclose(
        batch.mean_utilization, scalar.mean_utilization, rtol=1e-9
    )
    rows = batch.rows()
    assert len(rows) == len(pts) and rows[0]["network"] == "vgg11"


def test_run_sweep_validates_engine():
    with pytest.raises(ValueError, match="engine"):
        run_sweep([SweepPoint("vgg11", "blockwise", 142)], engine="gpu")


def test_fabric_eval_fills_latency_columns_bit_equal():
    """With a FabricEval, both engines fill p50/p95/p99 — the batched
    virtual-time path and the scalar event engine to the last bit — and the
    serving frontier (throughput, p99, utilization) becomes available."""
    from repro.dse import FabricEval, LATENCY_OBJECTIVES

    pts = design_grid(
        networks=("vgg11",),
        policies=("weight_based", "blockwise", "latency_aware"),
        pe_multipliers=(1.7, 2.4),
    )
    fe = FabricEval(load_frac=0.6, n_requests=60, seed=0)
    batch = run_sweep(pts, fabric=fe, **FAST_KW)
    scalar = run_sweep(pts, fabric=fe, engine="scalar", **FAST_KW)
    np.testing.assert_array_equal(batch.arrays_used, scalar.arrays_used)
    for col in ("p50_cycles", "p95_cycles", "p99_cycles"):
        b, s = getattr(batch, col), getattr(scalar, col)
        assert np.all(np.isfinite(b))
        np.testing.assert_array_equal(b, s)
    assert np.all(batch.p99_cycles >= batch.p95_cycles)
    assert np.all(batch.p95_cycles >= batch.p50_cycles)
    assert "p99_ms" in batch.rows()[0]
    idx = pareto_frontier(batch, LATENCY_OBJECTIVES)
    assert 0 < len(idx) <= len(pts)
    # the layer-wise weight_based designs have strictly worse tails than
    # block-wise designs at the same budget (the PR-1 acceptance, now a
    # first-class sweep column)
    wb = [i for i, p in enumerate(batch.points) if p.policy == "weight_based"]
    bw = [i for i, p in enumerate(batch.points) if p.policy == "blockwise"]
    assert np.all(batch.p99_cycles[wb] > batch.p99_cycles[bw])


def test_fabric_columns_absent_without_fabric_eval():
    pts = design_grid(networks=("vgg11",), pe_multipliers=(1.7,))
    res = run_sweep(pts, **FAST_KW)
    assert res.p99_cycles is None
    with pytest.raises(ValueError, match="FabricEval"):
        res.objectives(("images_per_sec", "p99_cycles"))


def test_frontier_on_sweep_is_sane():
    pts = design_grid(networks=("vgg11",), pe_multipliers=(1.0, 2.0, 4.0))
    res = run_sweep(pts, **FAST_KW)
    idx = pareto_frontier(res)
    assert 0 < len(idx) <= len(pts)
    # no frontier point may dominate another frontier point
    vals = res.objectives(("arrays_total", "images_per_sec", "mean_utilization"))
    assert pareto_mask(vals[idx], [False, True, True]).all()
    # restricted to (arrays, img/s) the frontier is a monotone trade-off:
    # more arrays must buy more throughput
    idx2 = pareto_frontier(
        res, objectives=(("arrays_total", False), ("images_per_sec", True))
    )
    order = np.argsort(res.arrays_total[idx2], kind="stable")
    assert (np.diff(res.images_per_sec[idx2][order]) >= -1e-9).all()
    # blockwise dominates at equal budget, so it must appear on the frontier
    assert any(res.points[i].policy == "blockwise" for i in idx)


# ------------------------------------------------------- acceptance (slow)
@pytest.mark.slow
def test_thousand_config_equivalence_and_speedup():
    """>=1000 (policy, PE-count, array-geometry) configs: batch == scalar
    element-wise; the batched engine is decisively faster (the >=20x
    acceptance number is recorded by `benchmarks/run.py dse`; the test
    asserts a conservative floor to stay robust on loaded CI machines)."""
    arrays = (
        DEFAULT_ARRAY,
        DEFAULT_ARRAY.variant(adc_bits=2),
        DEFAULT_ARRAY.variant(rows=256, cols=256),
    )
    pts = design_grid(
        networks=("vgg11",),
        pe_multipliers=tuple(np.linspace(1.0, 6.0, 67)),
        arrays=arrays,
    )
    assert len(pts) >= 1000
    kw = dict(profile_images=1, sample_patches=64)
    run_sweep(pts, **kw)  # compile
    batch = run_sweep(pts, **kw)
    scalar = run_sweep(pts, engine="scalar", **kw)
    np.testing.assert_array_equal(batch.arrays_used, scalar.arrays_used)
    for col in ("total_cycles", "images_per_sec", "mean_utilization"):
        np.testing.assert_allclose(
            getattr(batch, col), getattr(scalar, col), rtol=1e-9, err_msg=col
        )
    speedup = scalar.elapsed_s / batch.elapsed_s
    assert speedup > 5.0, f"batched sweep only {speedup:.1f}x faster"
