"""Sharding rules + dry-run machinery on small meshes/configs (no 512-device
flag needed: uses the smoke configs on a 1x1 mesh, and exercises the
PartitionSpec rules against a fake 16x16 mesh shape)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_is_defined, get_config
from repro.distrib.context import set_mesh, use_mesh
from repro.distrib.sharding import (
    cache_specs,
    data_specs,
    moe_ep_axes,
    opt_specs,
    param_specs,
)
from repro.launch.mesh import make_cpu_mesh
from repro.models import lm
from repro.optim.adamw import adamw_init


class FakeMesh:
    """Duck-typed mesh for spec-rule tests (shape dict + axis names)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(shape.values())))


MESH16 = FakeMesh({"data": 16, "model": 16})


def _abstract_params(cfg):
    return jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))


def test_dense_param_specs():
    cfg = get_config("glm4-9b")
    specs = param_specs(cfg, _abstract_params(cfg), MESH16)
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    # kv heads (2) don't divide tp=16 -> replicated
    assert specs["layers"]["attn"]["wk"] == P(None, None, None)
    assert specs["layers"]["mlp"]["w_up"] == P(None, None, "model")
    assert specs["layers"]["mlp"]["w_down"] == P(None, "model", None)


def test_moe_ep_axes_selection():
    ds = get_config("deepseek-v2-236b")
    assert moe_ep_axes(ds, MESH16) == ("model",)  # 160 % 16 == 0
    import dataclasses

    repl = dataclasses.replace(ds.moe, replication=tuple([2] * 96 + [1] * 64))
    assert moe_ep_axes(ds.with_(moe=repl), MESH16) == ("data", "model")  # 256
    grok = get_config("grok-1-314b")
    assert moe_ep_axes(grok, MESH16) == ()  # 8 divides nothing -> TP


def test_expert_specs_follow_ep_choice():
    cfg = get_config("deepseek-v2-236b")
    specs = param_specs(cfg, _abstract_params(cfg), MESH16)
    assert specs["layers"]["moe"]["experts"]["w_up"] == P(None, "model", None, None)
    # shared expert replicated (EP splits tokens over 'model')
    assert specs["layers"]["moe"]["shared"]["w_up"] == P(None, None, None)


def test_ssm_specs_shard_heads():
    cfg = get_config("mamba2-370m")
    specs = param_specs(cfg, _abstract_params(cfg), MESH16)
    assert specs["layers"]["mamba"]["wx"] == P(None, None, "model")
    assert specs["layers"]["mamba"]["out_proj"] == P(None, "model", None)
    assert specs["layers"]["mamba"]["wB"] == P(None, None, None)


def test_opt_specs_zero1():
    cfg = get_config("glm4-9b")
    p = _abstract_params(cfg)
    o = jax.eval_shape(lambda: adamw_init(p))
    specs = opt_specs(cfg, o, MESH16)
    # stacked layer moments pick up the data axis on the layer dim (ZeRO-1)
    wq = specs["m"]["layers"]["attn"]["wq"]
    flat = [a for e in wq if e is not None for a in ((e,) if isinstance(e, str) else e)]
    assert "data" in flat and "model" in flat
    assert specs["step"] == P()


def test_cache_specs_seq_shard_fallback():
    cfg = get_config("grok-1-314b")  # kv=8 < tp=16
    c = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024, jnp.bfloat16))
    specs = cache_specs(cfg, c, MESH16)
    assert specs["layers"]["k"] == P(None, "data", "model", None, None)
    cfg2 = get_config("zamba2-1.2b")  # kv=32 divides 16 -> heads sharded
    c2 = jax.eval_shape(lambda: lm.init_cache(cfg2, 128, 1024, jnp.bfloat16))
    specs2 = cache_specs(cfg2, c2, MESH16)
    assert specs2["shared_sites"]["k"] == P(None, "data", None, "model", None)


def test_data_specs_divisibility():
    assert data_specs(MESH16, 256) == P(("data",))
    assert data_specs(MESH16, 7) == P()


def test_cell_skip_rules():
    n_skipped = 0
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, reason = cell_is_defined(arch, shape)
            if not ok:
                n_skipped += 1
                assert shape == "long_500k"
                assert "quadratic" in reason
    assert n_skipped == 8  # all but mamba2 + zamba2 skip long_500k


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-v2-236b", "mamba2-370m"])
def test_smoke_cell_lowers_on_cpu_mesh(arch):
    """The dry-run machinery end-to-end at smoke scale on the 1x1 mesh."""
    from repro.launch.specs import build_cell

    mesh = make_cpu_mesh()
    cell = build_cell(arch, "train_4k", mesh, smoke=True)
    with mesh:
        compiled = (
            jax.jit(cell.fn, in_shardings=cell.in_shardings, out_shardings=cell.out_shardings)
            .lower(*cell.args)
            .compile()
        )
    assert compiled.memory_analysis().temp_size_in_bytes >= 0
    set_mesh(None)


def test_moe_shard_map_matches_local_path():
    """EP dispatch through shard_map == the purely local dispatch path."""
    import dataclasses

    cfg = get_config("deepseek-v2-236b", smoke=True).with_(dtype="float32")
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)

    set_mesh(None)
    logits_local, _ = lm.forward(params, cfg, toks)

    mesh = make_cpu_mesh()  # 1x1: shard_map path with degenerate axes
    with use_mesh(mesh), mesh:
        logits_dist = jax.jit(lambda p, t: lm.forward(p, cfg, t)[0])(params, toks)
    set_mesh(None)
    np.testing.assert_allclose(
        np.asarray(logits_local, np.float32),
        np.asarray(logits_dist, np.float32),
        rtol=2e-3,
        atol=2e-3,
    )
