"""Pipeline-parallel executor: exactness vs sequential reference.

The SPMD pipeline needs >1 device on the 'pipe' axis, and device count is
locked at first jax init — so the multi-device cases run in a SUBPROCESS
with XLA_FLAGS=--xla_force_host_platform_device_count=4 (same pattern as
launch/dryrun.py).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.alloc.pipeline_stages import partition_stages
from repro.distrib.pipeline import bubble_fraction

SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distrib.pipeline import make_pipeline_fn, stack_stages, bubble_fraction

L, D, MB, M = 8, 16, 2, 6
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (L, D, D)) / np.sqrt(D)
b = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
layers = {"w": w, "b": b}
xs = jax.random.normal(jax.random.fold_in(key, 2), (M, MB, D))

def layer_apply(p, x):  # one layer
    return jnp.tanh(x @ p["w"] + p["b"])

def stage_fn(stage_params, x):
    def body(xx, pl):
        return layer_apply(pl, xx), None
    y, _ = jax.lax.scan(body, x, stage_params)
    return y

# sequential reference (original layer order!)
ref = xs
def seq_body(xx, i):
    pl = jax.tree.map(lambda a: a[i], layers)
    return layer_apply(pl, xx), None
ref, _ = jax.lax.scan(lambda xx, i: seq_body(xx, i), xs, jnp.arange(L))

mesh = jax.make_mesh((4,), ("pipe",))
costs = np.ones(L)  # equal costs => stage order == layer order
stages, loads = stack_stages(layers, costs, 4)
fn = make_pipeline_fn(stage_fn, mesh, n_micro=M)
with mesh:
    out = jax.jit(fn)(stages, xs)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)

# gradients flow through the schedule (fill-drain backward via AD)
def loss(stages, xs):
    return jnp.sum(fn(stages, xs) ** 2)
with mesh:
    g = jax.jit(jax.grad(loss))(stages, xs)
assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
assert any(float(jnp.abs(x).max()) > 0 for x in jax.tree.leaves(g))

# collective-permute is actually on the wire
with mesh:
    txt = jax.jit(fn).lower(stages, xs).compile().as_text()
assert "collective-permute" in txt
print("PIPELINE_OK", bubble_fraction(4, M))
"""


def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_PROG],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "PIPELINE_OK" in r.stdout


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
    # more microbatches amortize the barrier (the paper's throughput-over-
    # latency trade in layer pipelining)
    assert bubble_fraction(4, 48) < bubble_fraction(4, 4)


def test_stage_stacking_preserves_order():
    import jax
    import jax.numpy as jnp

    from repro.distrib.pipeline import stack_stages

    L = 12
    layers = {"w": jnp.arange(L, dtype=jnp.float32)}
    costs = np.ones(L)
    stages, loads = stack_stages(layers, costs, 3)
    got = np.asarray(jax.tree.leaves(stages)[0])
    # contiguous, order-preserving (sequential layers must not permute)
    np.testing.assert_array_equal(got, np.arange(12.0).reshape(3, 4))
    assert loads.tolist() == [4.0, 4.0, 4.0]


def test_report_stage_plan_quantifies_raggedness():
    from repro.distrib.pipeline import report_stage_plan

    costs = np.array([10, 1, 1, 1, 10, 1, 1, 1, 10, 1, 1, 1], dtype=float)
    rep = report_stage_plan(costs, 3)
    # equal contiguous split puts one heavy layer per stage here: no gain
    assert rep["ragged_gain"] >= 1.0
    skew = np.array([1, 1, 1, 1, 1, 1, 1, 1, 20, 1, 1, 1], dtype=float)
    rep2 = report_stage_plan(skew, 3)
    assert rep2["ragged_gain"] >= 1.0  # optimal never worse