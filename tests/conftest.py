"""Tier-1 fast/slow split.

``pyproject.toml`` deselects ``-m slow`` by default so the PR job stays
under five minutes; the nightly CI job runs ``pytest -m slow``.  Besides
explicitly marked tests (full DSE sweeps), the heaviest per-architecture
smoke params are moved to the slow tier here — the fast tier keeps one
representative of every model family (SSM: mamba2, MoE: grok, dense GQA:
glm4/qwen2.5, VL: qwen2-vl, enc-dec: whisper)."""

import pytest

SLOW_ARCHES = {"zamba2-1.2b", "nemotron-4-15b", "deepseek-v2-236b", "qwen1.5-110b"}
SLOW_MODULES = {"test_arch_smoke.py"}


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.path.name not in SLOW_MODULES:
            continue
        callspec = getattr(item, "callspec", None)
        if callspec and any(
            v in SLOW_ARCHES for v in callspec.params.values() if isinstance(v, str)
        ):
            item.add_marker(pytest.mark.slow)
