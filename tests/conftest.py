"""Tier-1 fast/slow split.

``pyproject.toml`` deselects ``-m slow`` by default so the PR job stays
under five minutes; the nightly CI job runs ``pytest -m slow``.  Besides
explicitly marked tests (full DSE sweeps), the heaviest per-architecture
smoke params are moved to the slow tier here — the fast tier keeps one
representative of every model family (SSM: mamba2, MoE: grok, dense GQA:
glm4/qwen2.5, VL: qwen2-vl, enc-dec: whisper)."""

import pytest

SLOW_ARCHES = {"zamba2-1.2b", "nemotron-4-15b", "deepseek-v2-236b", "qwen1.5-110b"}
SLOW_MODULES = {"test_arch_smoke.py"}


def pytest_collection_modifyitems(config, items):
    for item in items:
        if item.path.name not in SLOW_MODULES:
            continue
        callspec = getattr(item, "callspec", None)
        if callspec and any(
            v in SLOW_ARCHES for v in callspec.params.values() if isinstance(v, str)
        ):
            item.add_marker(pytest.mark.slow)


# ------------------------------------------------------ shared CIM profiles
# Profiling runs a quantized network forward; before this cache nearly every
# CIM/fabric test module re-ran it for the same (network, images, sample)
# parameters.  Modules take the session-scoped ``profiled`` factory instead,
# so each distinct parameter set is captured exactly once per test session.
_PROFILED_CACHE: dict = {}


@pytest.fixture(scope="session")
def profiled():
    """Factory: ``profiled(network, n_images=1, sample_patches=128)`` ->
    (spec, NetworkProfile), cached across all test modules."""
    from repro.core.cim import profile_network, resnet18_imagenet, vgg11_cifar10

    spec_fns = {"resnet18": resnet18_imagenet, "vgg11": vgg11_cifar10}

    def get(network: str, n_images: int = 1, sample_patches: int = 128, seed: int = 0):
        key = (network, n_images, sample_patches, seed)
        if key not in _PROFILED_CACHE:
            spec = spec_fns[network]()
            _PROFILED_CACHE[key] = (
                spec,
                profile_network(
                    spec,
                    n_images=n_images,
                    sample_patches=sample_patches,
                    seed=seed,
                ),
            )
        return _PROFILED_CACHE[key]

    return get
