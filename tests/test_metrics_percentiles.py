"""The shared in-kernel percentile helper (fabric/metrics.percentile_kernel)
is the single implementation behind latency_stats AND the virtual-time
kernel's in-jit reduction — pinned here on the edge cases that historically
diverge between scalar and batch paths: empty batch, a single request, and
all-tied latencies.
"""

import numpy as np
import pytest

from repro.fabric.metrics import latency_stats, percentile_kernel

QS = (50.0, 95.0, 99.0)


def _jnp():
    jax = pytest.importorskip("jax")
    from jax.experimental import enable_x64

    return jax, enable_x64


def test_single_request_scalar_equals_batch():
    lat = np.asarray([1234.5])
    ref = percentile_kernel(np, lat, QS)
    np.testing.assert_array_equal(ref, [1234.5] * 3)
    jax, enable_x64 = _jnp()
    import jax.numpy as jnp

    with enable_x64():
        out = np.asarray(jax.jit(lambda x: percentile_kernel(jnp, x, QS))(lat))
    np.testing.assert_array_equal(out, ref)


def test_all_ties_scalar_equals_batch():
    lat = np.full(37, 42.0)
    ref = percentile_kernel(np, lat, QS)
    np.testing.assert_array_equal(ref, [42.0] * 3)
    jax, enable_x64 = _jnp()
    import jax.numpy as jnp

    with enable_x64():
        out = np.asarray(jax.jit(lambda x: percentile_kernel(jnp, x, QS))(lat))
    np.testing.assert_array_equal(out, ref)


def test_general_batch_matches_scalar_bitwise():
    rng = np.random.default_rng(0)
    lat = rng.exponential(100.0, size=501)
    ref = percentile_kernel(np, lat, QS)
    np.testing.assert_array_equal(ref, np.percentile(lat, [50, 95, 99]))
    jax, enable_x64 = _jnp()
    import jax.numpy as jnp

    with enable_x64():
        out = np.asarray(jax.jit(lambda x: percentile_kernel(jnp, x, QS))(lat))
    np.testing.assert_allclose(out, ref, rtol=1e-12)


def test_empty_batch_contract():
    """Zero requests: the result-container level defines the stats as zeros
    (the helper itself is never called on empty input — latency_stats
    guards, and VirtualTimeFabric.run_batch early-returns)."""
    st = latency_stats(np.asarray([]))
    assert (st.n, st.mean, st.p50, st.p95, st.p99, st.max) == (0, 0, 0, 0, 0, 0)


def test_latency_stats_uses_the_shared_kernel():
    lat = np.asarray([3.0, 1.0, 2.0, 10.0])
    st = latency_stats(lat)
    p50, p95, p99 = percentile_kernel(np, lat, QS)
    assert (st.p50, st.p95, st.p99) == (p50, p95, p99)
    assert st.n == 4 and st.max == 10.0
