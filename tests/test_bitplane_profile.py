"""Interpret-mode coverage of the Pallas bit-plane popcount kernel.

Mirrors test_zskip_masks.py: the kernel's contract — per-plane '1' counts
and zero-skip cycle costs for arbitrary uint8 patch matrices sliced into
word-line blocks — is checked against the ``np.unpackbits`` reference on
random inputs, the all-zero / all-255 edge cases, non-divisible row counts
(zero-padded last block), and swept (rows_per_read, cycles_per_read), plus
a hypothesis property over arbitrary uint8 arrays.  Everything runs with
``interpret=True`` so CI exercises the Pallas path without a TPU.
"""

import numpy as np
import pytest

from repro.core.cim.cost import ArrayConfig, bitplane_ones, zskip_cycles
from repro.kernels.bitplane_profile import bitplane_block_profile, bitplane_profile


def _reference(q, block_rows, rows_per_read, cycles_per_read):
    """np.unpackbits per row slice — the profiler's original math."""
    s, rows = q.shape
    n_blocks = -(-rows // block_rows)
    ones = np.zeros((s, n_blocks, 8), np.int64)
    cyc = np.zeros((s, n_blocks), np.int64)
    for b in range(n_blocks):
        sl = q[:, b * block_rows : min((b + 1) * block_rows, rows)]
        ones[:, b] = bitplane_ones(sl)
        reads = np.maximum(1, -(-ones[:, b] // rows_per_read))
        cyc[:, b] = cycles_per_read * reads.sum(axis=-1)
    return ones, cyc


@pytest.mark.parametrize("s,rows,block_rows", [(8, 256, 128), (16, 300, 128), (4, 100, 256), (32, 128, 64)])
@pytest.mark.parametrize("rows_per_read", [4, 8, 16])
def test_kernel_matches_unpackbits_reference(s, rows, block_rows, rows_per_read):
    rng = np.random.default_rng(s + rows + rows_per_read)
    q = rng.integers(0, 256, size=(s, rows), dtype=np.uint8)
    ones, cyc = bitplane_profile(
        q, block_rows=block_rows, rows_per_read=rows_per_read, cycles_per_read=8,
        interpret=True,
    )
    ref_ones, ref_cyc = _reference(q, block_rows, rows_per_read, 8)
    np.testing.assert_array_equal(ones, ref_ones)
    np.testing.assert_array_equal(cyc, ref_cyc)


def test_kernel_matches_zskip_cycles_on_full_block():
    """One exact-width block == zskip_cycles on the raw patch matrix."""
    rng = np.random.default_rng(0)
    q = rng.integers(0, 256, size=(16, 128), dtype=np.uint8)
    cfg = ArrayConfig()  # rows_per_read=8, cycles_per_read=8
    _, cyc = bitplane_profile(
        q, block_rows=128, rows_per_read=cfg.rows_per_read,
        cycles_per_read=cfg.cycles_per_read, interpret=True,
    )
    np.testing.assert_array_equal(cyc[:, 0], zskip_cycles(q, cfg))


def test_all_zero_patches_cost_the_floor():
    """Zero input -> zero '1's everywhere -> 1 mandatory read per plane."""
    q = np.zeros((4, 200), np.uint8)
    ones, cyc = bitplane_profile(
        q, block_rows=128, rows_per_read=8, cycles_per_read=8, interpret=True
    )
    assert ones.sum() == 0
    np.testing.assert_array_equal(cyc, np.full((4, 2), 8 * 8))


def test_all_ones_patches_cost_the_ceiling():
    """All-255 input -> every row active in every plane -> baseline reads,
    and the zero-padded last block counts only its true rows."""
    q = np.full((3, 192), 255, np.uint8)
    ones, cyc = bitplane_profile(
        q, block_rows=128, rows_per_read=8, cycles_per_read=8, interpret=True
    )
    np.testing.assert_array_equal(ones[:, 0, :], np.full((3, 8), 128))
    np.testing.assert_array_equal(ones[:, 1, :], np.full((3, 8), 64))
    np.testing.assert_array_equal(cyc[:, 0], np.full(3, 8 * 8 * (128 // 8)))
    np.testing.assert_array_equal(cyc[:, 1], np.full(3, 8 * 8 * (64 // 8)))


def test_raw_block_entry_shapes():
    q = np.zeros((2, 4, 64), np.int32)
    ones, cyc = bitplane_block_profile(q, interpret=True)
    assert ones.shape == (2, 8, 4) and cyc.shape == (2, 4)


def test_bitplane_profile_validates_input():
    with pytest.raises(TypeError, match="uint8"):
        bitplane_profile(np.zeros((2, 8), np.int32), block_rows=8, interpret=True)
    with pytest.raises(ValueError, match="rows"):
        bitplane_profile(np.zeros(8, np.uint8), block_rows=8, interpret=True)


# ------------------------------------------------------------- hypothesis
try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra.numpy import arrays

    @given(
        q=arrays(
            np.uint8,
            st.tuples(st.integers(1, 12), st.integers(1, 80)),
            elements=st.integers(0, 255),
        ),
        block_rows=st.sampled_from([16, 32, 64, 128]),
        adc_bits=st.integers(1, 4),
    )
    @settings(max_examples=40, deadline=None)
    def test_popcount_property_vs_unpackbits(q, block_rows, adc_bits):
        """For ARBITRARY uint8 matrices the kernel's per-plane counts and
        cycles equal the np.unpackbits reference on every block slice."""
        k = 2**adc_bits
        ones, cyc = bitplane_profile(
            q, block_rows=block_rows, rows_per_read=k, cycles_per_read=8,
            interpret=True,
        )
        ref_ones, ref_cyc = _reference(q, block_rows, k, 8)
        np.testing.assert_array_equal(ones, ref_ones)
        np.testing.assert_array_equal(cyc, ref_cyc)

    @given(
        q=arrays(
            np.uint8,
            st.tuples(st.integers(1, 8), st.integers(1, 40)),
            elements=st.integers(0, 255),
        )
    )
    @settings(max_examples=40, deadline=None)
    def test_bitplane_ones_jax_equals_numpy(q):
        """cost.bitplane_ones: the shift-and-mask jax path == unpackbits."""
        import jax.numpy as jnp

        np.testing.assert_array_equal(
            np.asarray(bitplane_ones(jnp.asarray(q), xp=jnp)), bitplane_ones(q)
        )

except ImportError:  # pragma: no cover - optional dev dep
    pass
