"""Network -> crossbar mapping tests (the paper's published counts)."""

import numpy as np

from repro.core.cim.network import resnet18_imagenet, vgg11_cifar10


def test_resnet18_counts_match_paper():
    spec = resnet18_imagenet()
    assert len(spec.layers) == 20  # "20 convolutional layers in ResNet18"
    assert spec.n_arrays == 5472  # "minimum number of arrays (5472)"
    assert spec.n_blocks == 247  # "there are 247 blocks"
    assert spec.min_pes(64) == 86  # "we begin at 86 PEs"


def test_fig5_layer10_tiling():
    """Fig 5: the 3x3x128x128 filter -> 72 arrays in a 9x8 grid."""
    spec = resnet18_imagenet()
    layer = next(l for l in spec.layers if l.name == "layer2.0.conv2")
    assert layer.n_blocks == 9
    assert layer.arrays_per_block == 8
    assert layer.n_arrays == 72


def test_layer15_block_count():
    """Paper: layer 15 (3x3x256x256) contains 18 blocks."""
    spec = resnet18_imagenet()
    layer = next(l for l in spec.layers if l.name == "layer3.1.conv1")
    assert layer.rows == 3 * 3 * 256
    assert layer.n_blocks == 18


def test_block_slices_cover_rows():
    for spec in (resnet18_imagenet(), vgg11_cifar10()):
        for layer in spec.layers:
            slices = layer.block_row_slices()
            assert len(slices) == layer.n_blocks
            covered = sum(s.stop - s.start for s in slices)
            assert covered == layer.rows
            assert slices[0].start == 0 and slices[-1].stop == layer.rows


def test_block_table_shape():
    spec = resnet18_imagenet()
    tbl = spec.block_table()
    assert tbl.shape == (247, 3)
    assert tbl[:, 0].max() == 19
    # widths are arrays_per_block of the owning layer
    for li, layer in enumerate(spec.layers):
        w = tbl[tbl[:, 0] == li][:, 2]
        assert np.all(w == layer.arrays_per_block)
