"""Property test pinning ``fabric.metrics.percentile_kernel`` to
``np.percentile``: the one shared reduction the jitted fabric kernel and the
scalar accounting path both use must agree with the numpy reference on
arbitrary shapes and percentile levels, including the degenerate cases
(single element, all-ties) where interpolation definitions diverge.

Standalone module: the tier-1 minimal CI image has no hypothesis, so the
whole file skips at import."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

from repro.fabric.metrics import percentile_kernel

_floats = st.floats(
    min_value=-1e12, max_value=1e12, allow_nan=False, allow_infinity=False
)


@settings(max_examples=200, deadline=None)
@given(
    lat=hnp.arrays(
        dtype=np.float64, shape=st.integers(min_value=1, max_value=400),
        elements=_floats,
    ),
    qs=st.lists(
        st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
        min_size=1,
        max_size=6,
    ),
)
def test_matches_numpy_percentile(lat, qs):
    got = percentile_kernel(np, lat, qs)
    want = np.percentile(lat, qs)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=100, deadline=None)
@given(value=_floats, n=st.integers(min_value=1, max_value=50))
def test_all_ties_collapse_to_the_value(value, n):
    lat = np.full(n, value)
    got = percentile_kernel(np, lat, (0.0, 50.0, 99.9, 100.0))
    np.testing.assert_array_equal(got, np.full(4, value))


@settings(max_examples=100, deadline=None)
@given(value=_floats, q=st.floats(min_value=0.0, max_value=100.0, allow_nan=False))
def test_single_element_is_that_element(value, q):
    got = percentile_kernel(np, np.array([value]), (q,))
    np.testing.assert_array_equal(got, np.array([value]))


def test_jax_path_matches_numpy_reference():
    """The same kernel under jit (float64) equals the numpy evaluation on a
    representative latency vector — the cross-``xp`` half of the pin."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    lat = rng.gamma(2.0, 1e4, size=257)
    qs = (0.0, 12.5, 50.0, 95.0, 99.0, 100.0)
    with jax.experimental.enable_x64():
        got = np.asarray(
            jax.jit(lambda x: percentile_kernel(jnp, x, qs))(jnp.asarray(lat))
        )
    np.testing.assert_allclose(got, np.percentile(lat, qs), rtol=1e-12)
