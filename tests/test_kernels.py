"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_alloc_eval import fused_alloc_eval
from repro.kernels.ssd_scan import ssd_chunk
from repro.kernels.zskip_matmul import zskip_matmul


# ----------------------------------------------------------- zskip_matmul
@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 384, 128), (384, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zskip_matmul_matches_ref(M, K, N, dtype):
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    # post-ReLU-like sparse activations: zero out ~half the tiles
    a = jax.nn.relu(jax.random.normal(k1, (M, K), dtype))
    tile_keep = jax.random.bernoulli(k2, 0.5, (M // 128, K // 128))
    a = a * jnp.repeat(jnp.repeat(tile_keep, 128, 0), 128, 1).astype(dtype)
    b = jax.random.normal(k2, (K, N), dtype)
    mask = ref.block_mask_ref(a, 128, 128)
    got = zskip_matmul(a, b, mask, interpret=True)
    want = ref.zskip_matmul_ref(a, b, mask, 128, 128)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_zskip_exactness_on_zero_tiles():
    """Skipping all-zero tiles must be EXACT (not approximate)."""
    a = jnp.zeros((256, 256), jnp.float32).at[:128, :128].set(1.0)
    b = jnp.ones((256, 128), jnp.float32)
    mask = ref.block_mask_ref(a, 128, 128)
    assert mask.tolist() == [[1, 0], [0, 0]]
    got = zskip_matmul(a, b, mask, interpret=True)
    want = a @ b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_zskip_op_wrapper():
    a = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (256, 256)))
    b = jax.random.normal(jax.random.PRNGKey(2), (256, 128))
    np.testing.assert_allclose(
        np.asarray(ops.zskip_matmul_op(a, b)), np.asarray(a @ b), rtol=2e-5, atol=2e-5
    )


# -------------------------------------------------------- flash_attention
@pytest.mark.parametrize("sq,sk", [(128, 128), (256, 256), (256, 512)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(sq, sk, causal, dtype):
    if causal and sq != sk:
        pytest.skip("causal requires square here")
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    bh, hd = 4, 64
    q = jax.random.normal(kq, (bh, sq, hd), dtype)
    k = jax.random.normal(kk, (bh, sk, hd), dtype)
    v = jax.random.normal(kv, (bh, sk, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_op_matches_model_sdpa():
    """Kernel == the model's _sdpa (the path it replaces)."""
    from repro.models.layers import _sdpa

    key = jax.random.PRNGKey(4)
    b, s, h, hd = 2, 128, 4, 64
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd), jnp.float32)
    got = ops.flash_attention_op(q, k, v, causal=True)
    want = _sdpa(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- fused_alloc_eval
def _fused_problem(seed=0, a=3, n=9, l=4, b=5, c=21):
    """Random fused allocate+eval problem with integer cycle statistics
    (the real banks are integer-valued float64) and tie-heavy bases."""
    rng = np.random.default_rng(seed)
    v = 2 * a
    base = rng.integers(40, 400, size=(a, n)).astype(np.float64)
    base[:, : n // 2] = base[:, n // 2 : n // 2 + n // 2]  # force grant ties
    cost = rng.integers(1, 5, size=n).astype(np.float64)
    # random one-hot partition of the (l, b) cells onto n units
    owner = rng.integers(0, n, size=(l, b))
    umap = np.zeros((n, l, b))
    umap[owner, np.arange(l)[:, None], np.arange(b)[None, :]] = 1.0
    banks = (
        rng.integers(1, 200, size=(v, l, b)).astype(np.float64),
        rng.integers(200, 400, size=(v, l, b)).astype(np.float64),
        rng.integers(1, 200, size=(v, l)).astype(np.float64),
        rng.integers(200, 400, size=(v, l)).astype(np.float64),
        rng.integers(1, 100, size=(v, l)).astype(np.float64),
    )
    b_mask = np.ones((l, b), dtype=bool)
    b_mask[1, b - 1 :] = False
    ppi = rng.integers(1, 30, size=l).astype(np.float64)
    width = rng.integers(1, 4, size=l).astype(np.float64)
    larr = rng.integers(1, 8, size=l).astype(np.float64)
    budgets = rng.integers(0, 60, size=c).astype(np.float64)
    budgets[0] = 0.0  # the proportional budget-0 ride-along
    a_idx = rng.integers(0, a, size=c).astype(np.int32)
    sel = (a_idx + a * rng.integers(0, 2, size=c)).astype(np.int32)
    lw = rng.integers(0, 2, size=c).astype(bool)
    r0 = rng.integers(1, 4, size=(c, n)).astype(np.float64)
    return base, cost, umap, banks, b_mask, ppi, width, larr, budgets, a_idx, sel, lw, r0


@pytest.mark.parametrize("block_configs", [8, 21, 64])
def test_fused_alloc_eval_matches_oracles(block_configs):
    """Interpret-mode smoke: replicas bit-equal to ``greedy_allocate_batch``
    (same kernel body — warm starts, ties, budget 0 included) and eval
    columns equal to the scalar ``_eval_kernel`` per config.  The block
    grid pads by repeating config 0; every tiling must agree."""
    from jax.experimental import enable_x64

    from repro.core.alloc.greedy import greedy_allocate_batch
    from repro.core.cim.simulate import _eval_kernel

    (base, cost, umap, banks, b_mask, ppi, width, larr,
     budgets, a_idx, sel, lw, r0) = _fused_problem()
    with enable_x64():
        T, ips, layer_T, util, r, rem = fused_alloc_eval(
            base, cost, umap, banks, b_mask, ppi, width, larr,
            budgets, a_idx, sel, lw, r0,
            n_images=16, clock_hz=1e9, block_configs=block_configs,
            interpret=True,
        )
    want = greedy_allocate_batch(
        base[a_idx], cost, budgets, initial_replicas=r0
    )
    np.testing.assert_array_equal(np.asarray(r), want.replicas)
    np.testing.assert_allclose(np.asarray(rem), want.leftover, rtol=0, atol=0)
    for i in range(budgets.size):
        dups = 1.0 + np.tensordot(want.replicas[i] - 1.0, umap, axes=1)
        tT, tips, tlt, tu = _eval_kernel(
            np, *banks, b_mask, ppi, width, larr, dups, bool(lw[i]),
            16, 1e9, sel=int(sel[i]),
        )
        np.testing.assert_allclose(np.asarray(T)[i], tT, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ips)[i], tips, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(layer_T)[i], tlt, rtol=1e-12)
        np.testing.assert_allclose(np.asarray(util)[i], tu, rtol=1e-12)


def test_fused_alloc_eval_budget_zero_is_warm_start_identity():
    """Budget 0 must return the warm start untouched — the contract that
    lets proportional configs ride through the greedy kernel as no-ops."""
    from jax.experimental import enable_x64

    (base, cost, umap, banks, b_mask, ppi, width, larr,
     budgets, a_idx, sel, lw, r0) = _fused_problem(seed=1)
    budgets[:] = 0.0
    with enable_x64():
        *_, r, rem = fused_alloc_eval(
            base, cost, umap, banks, b_mask, ppi, width, larr,
            budgets, a_idx, sel, lw, r0, interpret=True,
        )
    np.testing.assert_array_equal(np.asarray(r), r0)
    np.testing.assert_array_equal(np.asarray(rem), np.zeros_like(budgets))


# -------------------------------------------------------------- ssd_chunk
@pytest.mark.parametrize("Q,H,P,N", [(32, 4, 16, 32), (64, 8, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunk_matches_ref(Q, H, P, N, dtype):
    key = jax.random.PRNGKey(5)
    nc = 3
    ks = jax.random.split(key, 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (nc, Q, H))) * 0.1
    cum = jnp.cumsum(-dt, axis=1).astype(dtype)
    xdt = (jax.random.normal(ks[1], (nc, Q, H, P)) * 0.5).astype(dtype)
    B = jax.random.normal(ks[2], (nc, Q, N), dtype)
    C = jax.random.normal(ks[3], (nc, Q, N), dtype)
    y, s = ssd_chunk(cum, xdt, B, C, head_block=min(4, H), interpret=True)
    y_ref, s_ref = ref.ssd_chunk_ref(cum, xdt, B, C)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32), rtol=tol, atol=tol
    )
    np.testing.assert_allclose(
        np.asarray(s, np.float32), np.asarray(s_ref, np.float32), rtol=tol, atol=tol
    )


def test_ssd_kernel_consistent_with_model_scan():
    """Kernel per-chunk outputs reproduce models.ssm.ssd_chunked end-to-end."""
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(6)
    b, s, h, p, n, chunk = 2, 64, 4, 16, 32, 32
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y_want, S_want = ssd_chunked(x, dt, A, B, C, chunk=chunk)

    # rebuild via kernel: per-batch chunked terms + jnp inter-chunk scan
    nc = s // chunk
    dtc = dt.reshape(b, nc, chunk, h)
    cum = jnp.cumsum(dtc * A, axis=2)
    xdt = (x * dt[..., None]).reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    outs = []
    for bi in range(b):
        y_intra, S_chunk = ssd_chunk(
            cum[bi], xdt[bi], Bc[bi], Cc[bi], head_block=h, interpret=True
        )
        chunk_decay = jnp.exp(cum[bi, :, -1, :])  # (nc, h)
        S = jnp.zeros((h, n, p))
        ys = []
        for c in range(nc):
            y_inter = jnp.einsum(
                "qh,qn,hnp->qhp", jnp.exp(cum[bi, c]), Cc[bi, c], S
            )
            ys.append(y_intra[c] + y_inter)
            S = chunk_decay[c][:, None, None] * S + S_chunk[c]
        outs.append(jnp.concatenate(ys, axis=0))
    y_got = jnp.stack(outs)
    np.testing.assert_allclose(
        np.asarray(y_got), np.asarray(y_want), rtol=2e-4, atol=2e-4
    )
