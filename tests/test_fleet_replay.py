"""Fleet-scale trace replay: blocked scan, streaming sketches, segments.

Three contracts, each pinned bit-for-bit where the design promises it:

  * the blocked request scan (``window=W``) is the SAME computation as the
    W=1 scan — non-overtaking means the W-unrolled body performs identical
    IEEE operations in identical order, for every W, engine and loop shape;
  * the streaming path (``run_stream``: in-kernel hashed service draws +
    in-carry sketch) equals the event engine run at the same hash seed and
    its own numpy replay, and its materializing baseline mode reproduces the
    stream sketch from the identical kernel;
  * segmented replay with NO allocation change is a no-op (bit-identical to
    the unsegmented run, stream and materializing), growth charges exactly
    ``DriftConfig.stall`` at each boundary, and shrink seams (the failure
    PR's re-allocation downward) kill the largest-virtual-time lanes —
    pinned bit-identical to the event engine replaying the same trajectory
    via ``degrade_plan_from_allocs`` + ``FabricSim(failures=...)``.
"""

import numpy as np
import pytest

from repro.core.cim import allocate, simulate
from repro.core.cim.simulate import CLOCK_HZ
from repro.fabric import (
    ClosedLoop,
    CoarsenConfig,
    DriftConfig,
    FabricSim,
    PoissonOpen,
    TraceReplay,
    VirtualTimeFabric,
    arrival_times,
    hash_service_indices,
    run_stream,
    run_trace_segments,
    segment_growth_plan,
)
from repro.fabric.vtime import _hash_salt


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=64)


@pytest.fixture(scope="module")
def setup(vgg):
    spec, prof = vgg
    bw = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    vt = VirtualTimeFabric(spec, prof)
    return spec, prof, bw, cap, vt


def _open_proc(cap, n=60, frac=0.6, seed=5):
    return PoissonOpen(n_requests=n, rate_per_cycle=frac * cap / CLOCK_HZ, seed=seed)


# ---------------------------------------------------------- blocked scan
@pytest.mark.parametrize("window", [2, 5, 8])
def test_window_bit_identical_open_loop_jax(setup, window):
    """W > 1 == W = 1, including W that does not divide N (epilogue)."""
    spec, prof, bw, cap, vt = setup
    proc = _open_proc(cap, n=61)
    ref = vt.run_batch([bw], proc, seed=3, engine="jax", window=1)
    got = vt.run_batch([bw], proc, seed=3, engine="jax", window=window)
    np.testing.assert_array_equal(got.completions, ref.completions)
    np.testing.assert_array_equal(got.arrivals, ref.arrivals)


def test_window_bit_identical_numpy(setup):
    spec, prof, bw, cap, vt = setup
    proc = _open_proc(cap, n=47)
    ref = vt.run_batch([bw], proc, seed=3, engine="numpy", window=1)
    got = vt.run_batch([bw], proc, seed=3, engine="numpy", window=5)
    np.testing.assert_array_equal(got.completions, ref.completions)


def test_window_clamped_to_closed_loop_concurrency(setup):
    """A closed loop admits from the completion ring: dispatch order only
    stays causal for W <= concurrency, so the kernel clamps — W=16 at
    concurrency 4 must equal W=1 (and the event engine)."""
    spec, prof, bw, cap, vt = setup
    proc = ClosedLoop(n_requests=30, concurrency=4)
    ref = FabricSim(spec, prof, bw, seed=1).run(proc)
    for engine in ("jax", "numpy"):
        got = vt.run_batch([bw], proc, seed=1, engine=engine, window=16)
        np.testing.assert_array_equal(got.completions[0], ref.completions)


def test_fused_pipeline_blocked_scan_unchanged(vgg):
    """The fused DSE fabric stage adopted the blocked scan (window=8
    default); any window must reproduce window=1 exactly."""
    pytest.importorskip("jax")
    from repro.core.cim.cost import DEFAULT_ARRAY
    from repro.dse.fused import get_fused_pipeline

    pipe = get_fused_pipeline("vgg11", DEFAULT_ARRAY, (6, 7), sample_patches=64)
    rng = np.random.default_rng(0)
    C, n = 3, 25
    a_idx = np.array([0, 1, 0], dtype=np.int32)
    dups = np.ones((C, pipe.L, pipe.B))
    lw = np.array([False, True, False])
    z = np.array([True, True, False])
    times = np.sort(rng.uniform(0, 1e6, size=(C, n)), axis=1)
    p1 = pipe.fabric_percentiles(a_idx, dups, lw, z, times, seed=2, window=1)
    p8 = pipe.fabric_percentiles(a_idx, dups, lw, z, times, seed=2, window=8)
    np.testing.assert_array_equal(p1, p8)


# ------------------------------------------------------------- hash draws
def test_hash_indices_vectorize_and_bound():
    salt = _hash_salt(7, 3)
    ix = hash_service_indices(np, salt, np.arange(11), 9, 64)
    assert ix.shape == (11, 9) and ix.dtype == np.int32
    assert ix.min() >= 0 and ix.max() < 64
    # request-scalar evaluation is the same stream (the in-kernel view)
    for r in range(11):
        np.testing.assert_array_equal(
            hash_service_indices(np, salt, r, 9, 64), ix[r]
        )


def test_hash_indices_jax_matches_numpy():
    pytest.importorskip("jax")
    import jax.numpy as jnp

    salt = _hash_salt(0, 1)
    ref = hash_service_indices(np, salt, np.arange(17), 5, 128)
    got = np.asarray(hash_service_indices(jnp, salt, jnp.arange(17), 5, 128))
    np.testing.assert_array_equal(got, ref)


# ------------------------------------------------------------- run_stream
def test_stream_equals_event_engine_hash_mode(setup):
    """The cross-engine pin at fleet seeds: FabricSim consuming the same
    counter hash produces the identical latency population — sketch bucket
    counts, exact min/max/mean and makespan all equal."""
    spec, prof, bw, cap, vt = setup
    proc = _open_proc(cap, n=80)
    fr = run_stream(vt, [bw], proc, seed=5, engine="jax", window=8)
    ref = FabricSim(spec, prof, bw, seed=5, service_sampling="hash").run(proc)
    lat = ref.completions - ref.arrivals
    s = fr.sketches[0]
    ref_sk = type(s).from_latencies(lat, s.config)
    np.testing.assert_array_equal(s.counts, ref_sk.counts)
    assert s.min == lat.min() and s.max == lat.max()
    np.testing.assert_allclose(s.mean, lat.mean(), rtol=1e-12)
    assert fr.makespan[0] == ref.completions.max()


def test_stream_numpy_equals_jax(setup):
    spec, prof, bw, cap, vt = setup
    proc = _open_proc(cap, n=50)
    a = run_stream(vt, [bw], proc, seed=2, engine="jax", window=8)
    b = run_stream(vt, [bw], proc, seed=2, engine="numpy", window=3)
    for sa, sb in zip(a.sketches, b.sketches):
        np.testing.assert_array_equal(sa.counts, sb.counts)
        assert (sa.min, sa.max, sa.mean, sa.m2) == (sb.min, sb.max, sb.mean, sb.m2)
    np.testing.assert_array_equal(a.makespan, b.makespan)


def test_materialize_baseline_same_kernel(setup):
    """materialize=True (the O(N)-memory baseline) runs the identical
    kernel: its in-carry sketch equals the streaming run's, and its exact
    percentiles bound the sketch estimates within config.rel_error."""
    spec, prof, bw, cap, vt = setup
    proc = _open_proc(cap, n=120)
    fr = run_stream(vt, [bw], proc, seed=5, engine="jax", window=8)
    fm = run_stream(vt, [bw], proc, seed=5, engine="jax", window=1, materialize=True)
    np.testing.assert_array_equal(fr.sketches[0].counts, fm.sketches[0].counts)
    assert fm.completions.shape == (1, 120)
    exact = fm.exact_percentiles
    rel = np.abs(fr.percentiles - exact) / exact
    assert rel.max() <= fr.sketches[0].config.rel_error
    # sketch min/max/mean are exact, not bucketized
    lat = (fm.completions - fm.arrivals)[0]
    assert fr.sketches[0].min == lat.min() and fr.sketches[0].max == lat.max()
    np.testing.assert_allclose(fr.sketches[0].mean, lat.mean(), rtol=1e-12)


def test_coarsen_is_pessimistic_and_close(setup):
    """Macro-job chunking may only push latency UP (the chunk barrier waits
    for the whole chunk) and stays within a loose documented band."""
    spec, prof, bw, cap, vt = setup
    proc = _open_proc(cap, n=80)
    exact = run_stream(vt, [bw], proc, seed=5, engine="numpy", window=4)
    co = run_stream(
        vt, [bw], proc, seed=5, engine="numpy", window=4,
        coarsen=CoarsenConfig(tail_lanes=2),
    )
    assert co.sketches[0].mean >= exact.sketches[0].mean
    assert co.percentiles[0, 2] <= 1.10 * exact.percentiles[0, 2]


# ------------------------------------------------------ segmented replay
@pytest.fixture(scope="module")
def growth(setup):
    spec, prof, bw, cap, vt = setup
    plan = segment_growth_plan(spec, prof, bw, budgets=[64, 128])
    return plan


def test_growth_plan_monotone_and_warm_started(setup, growth):
    spec, prof, bw, cap, vt = setup
    used = [a.arrays_used for a in growth]
    assert used[0] == bw.arrays_used and used[1] > used[0] and used[2] > used[1]
    for prev, cur in zip(growth, growth[1:]):
        for dp, dc in zip(prev.block_dups, cur.block_dups):
            assert np.all(np.asarray(dc) >= np.asarray(dp))  # growth-only


@pytest.mark.parametrize("stream", [True, False])
def test_segmented_noop_is_bit_identical(setup, stream):
    """Same allocation in every segment, zero growth -> segmentation is
    invisible: stream mode equals the unsegmented streaming sketch, and
    materializing mode equals run_batch completions."""
    spec, prof, bw, cap, vt = setup
    times = arrival_times(_open_proc(cap, n=37))
    bounds = [times[12] + 0.5, times[25] + 0.5]
    res = run_trace_segments(
        vt, [bw, bw, bw], times, bounds, seed=4, engine="numpy", window=4,
        stream=stream, pad_to=8,
    )
    assert res.total_stall_cycles.max() == 0.0
    if stream:
        ref = run_stream(
            vt, [bw], TraceReplay(times), seed=4, engine="numpy", window=4
        )
        np.testing.assert_array_equal(res.sketches[0].counts, ref.sketches[0].counts)
        assert res.sketches[0].mean == ref.sketches[0].mean
        np.testing.assert_array_equal(res.makespan, ref.makespan)
    else:
        ref = vt.run_batch([bw], TraceReplay(times), seed=4, engine="numpy")
        np.testing.assert_array_equal(res.completions, ref.completions)


def test_segmented_growth_charges_stall(setup, growth):
    """A boundary that reprograms arrays freezes every lane until
    boundary + DriftConfig.stall(added) — completions after the boundary
    can only move later vs the no-growth replay, and the reports carry the
    exact event-engine stall."""
    spec, prof, bw, cap, vt = setup
    drift = DriftConfig()
    times = arrival_times(_open_proc(cap, n=40))
    bounds = [float(times[15]) + 0.5, float(times[28]) + 0.5]
    res = run_trace_segments(
        vt, [[a] for a in growth], times, bounds, drift=drift, seed=4,
        engine="numpy", window=4, stream=False, pad_to=8,
    )
    added = [s.arrays_added[0] for s in res.segments]
    stalls = [s.stall_cycles[0] for s in res.segments]
    assert added[0] == 0 and added[1] > 0 and added[2] > 0
    for a, s in zip(added[1:], stalls[1:]):
        assert s == drift.stall(int(a))
    flat = run_trace_segments(
        vt, [bw, bw, bw], times, bounds, seed=4, engine="numpy", window=4,
        stream=False, pad_to=8,
    )
    # the first segment is untouched; later requests never complete earlier
    # than the frozen fabric allows and the stall is visible in at least one
    n0 = res.segments[0].n_requests
    np.testing.assert_array_equal(
        res.completions[0, :n0], flat.completions[0, :n0]
    )
    assert res.completions[0, n0:].min() >= bounds[0] + stalls[1]


def test_segmented_stream_engines_and_padding_agree(setup, growth):
    """Growth replay is engine- and padding-invariant: numpy at pad 8 and
    jit at pad 16 (different numbers of carry-masked padding requests, same
    valid work) produce identical sketches and makespans."""
    spec, prof, bw, cap, vt = setup
    times = arrival_times(_open_proc(cap, n=40))
    bounds = [float(times[15]) + 0.5, float(times[28]) + 0.5]
    segs = [[a] for a in growth]
    st = run_trace_segments(
        vt, segs, times, bounds, seed=4, engine="numpy", window=4,
        stream=True, pad_to=8,
    )
    mt = run_trace_segments(
        vt, segs, times, bounds, seed=4, engine="jax", window=4,
        stream=True, pad_to=16,
    )
    np.testing.assert_array_equal(st.sketches[0].counts, mt.sketches[0].counts)
    assert st.sketches[0].mean == mt.sketches[0].mean
    np.testing.assert_array_equal(st.makespan, mt.makespan)


def test_segmented_rejects_closed_loop_and_bad_boundaries(setup, growth):
    spec, prof, bw, cap, vt = setup
    times = np.linspace(0.0, 1e6, 10)
    with pytest.raises(ValueError, match="open-loop"):
        run_trace_segments(
            vt, [bw, bw], ClosedLoop(10, 4), [5e5], engine="numpy"
        )
    with pytest.raises(ValueError, match="boundaries"):
        run_trace_segments(vt, [bw, bw, bw], times, [5e5], engine="numpy")


@pytest.mark.parametrize("engine", ["numpy", "jax"])
def test_segmented_shrink_matches_event_engine(setup, growth, engine):
    """A shrink seam (grown -> base allocation) is legal and bit-identical
    across engines: the vtime kernel retires the largest-free-time lanes to
    +inf, the event engine pops the same multiset via ``ServerPool.kill`` —
    both driven by one ``degrade_plan_from_allocs`` trajectory."""
    if engine == "jax":
        pytest.importorskip("jax")
    from repro.fabric import degrade_plan_from_allocs

    spec, prof, bw, cap, vt = setup
    times = arrival_times(_open_proc(cap, n=50))
    bounds = [float(times[20]) + 0.5]
    segs = [growth[1], growth[0]]
    res = run_trace_segments(
        vt, segs, times, bounds, seed=4, engine=engine, stream=False, pad_to=8
    )
    # pure shrink reprograms nothing: no arrays added, no stall charged
    assert all(s.arrays_added[0] == 0 for s in res.segments)
    assert res.total_stall_cycles.max() == 0.0
    plan = degrade_plan_from_allocs(
        spec, segs, bounds, horizon=float(times[-1])
    )
    ref = FabricSim(spec, prof, growth[1], seed=4, failures=plan).run(
        TraceReplay(times)
    )
    np.testing.assert_array_equal(res.completions[0], ref.completions)


def test_segmented_shrink_to_identical_is_noop(setup, growth):
    """A seam whose 'shrink' lands back on the very same dups is invisible:
    bit-identical to the unsegmented replay (the degenerate case separating
    'allocation changed' from 'boundary exists')."""
    spec, prof, bw, cap, vt = setup
    times = arrival_times(_open_proc(cap, n=40))
    bounds = [float(times[15]) + 0.5]
    res = run_trace_segments(
        vt, [growth[1], growth[1]], times, bounds, seed=4, engine="numpy",
        stream=False, pad_to=8,
    )
    ref = vt.run_batch([growth[1]], TraceReplay(times), seed=4, engine="numpy")
    np.testing.assert_array_equal(res.completions, ref.completions)


def test_growth_plan_negative_budget_shrinks(setup):
    """segment_growth_plan accepts negative budgets: greedy_release frees
    the lowest-cost-per-latency replicas, never below one copy per block."""
    spec, prof, bw, cap, vt = setup
    plan = segment_growth_plan(spec, prof, bw, budgets=[64, -64])
    used = [a.arrays_used for a in plan]
    assert used[1] > used[0] and used[2] < used[1]
    for d in plan[2].block_dups:
        assert np.all(np.asarray(d) >= 1)
    # release frees whole replicas, so it may overshoot the request by at
    # most one replica's cost — never more
    max_cost = max(l.arrays_per_block for l in spec.layers)
    assert used[1] - used[2] >= 64 and used[1] - used[2] < 64 + max_cost
