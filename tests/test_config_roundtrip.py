"""Hypothesis round-trips for the design-space configuration records.

Geometry sweeps build variants with ``ArrayConfig.variant(...)`` and retile
networks with ``with_array``; a field silently dropped by either (the
classic frozen-dataclass ``replace`` pitfall when a field is renamed or
computed) would make every sweep over that axis a silent no-op.  These
properties draw random field assignments — including the PR-4 topology
fields (``noc_hop_cycles``, ``noc_flit_bytes``) — and assert every field
round-trips through variant construction, network retargeting, and
profile-cache keying.  Same for ``FabricTopology.variant``.
"""

import dataclasses

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.cim import DEFAULT_ARRAY, FabricTopology, vgg11_cifar10, with_array
from repro.core.cim.cost import ArrayConfig

ARRAY_FIELDS = {
    "rows": st.sampled_from([64, 128, 256]),
    "cols": st.sampled_from([64, 128, 256]),
    "cell_bits": st.sampled_from([1, 2]),
    "weight_bits": st.sampled_from([4, 8]),
    "input_bits": st.sampled_from([4, 8]),
    "adc_bits": st.integers(1, 4),
    "adc_share": st.sampled_from([4, 8, 16]),
    "noc_hop_cycles": st.integers(1, 8),
    "noc_flit_bytes": st.sampled_from([8, 16, 32]),
}


@st.composite
def array_changes(draw):
    names = draw(
        st.lists(st.sampled_from(sorted(ARRAY_FIELDS)), min_size=1, unique=True)
    )
    return {n: draw(ARRAY_FIELDS[n]) for n in names}


@given(array_changes())
@settings(max_examples=40, deadline=None)
def test_array_variant_roundtrip(changes):
    """Every changed field lands; every untouched field keeps its default —
    no silent drops through the variant constructor."""
    arr = DEFAULT_ARRAY.variant(**changes)
    for f in dataclasses.fields(ArrayConfig):
        expect = changes.get(f.name, getattr(DEFAULT_ARRAY, f.name))
        assert getattr(arr, f.name) == expect, f.name
    # frozen+eq: the variant keys caches distinctly from the default iff
    # something actually changed
    assert (arr == DEFAULT_ARRAY) == all(
        changes[k] == getattr(DEFAULT_ARRAY, k) for k in changes
    )
    # round-trip again through an identity variant
    assert arr.variant() == arr


@given(array_changes())
@settings(max_examples=15, deadline=None)
def test_with_array_carries_every_field(changes):
    """Retiling a network must propagate the FULL ArrayConfig to every
    layer — topology fields included, so multi-chip cost models derived
    from the layer's array see the swept values."""
    arr = DEFAULT_ARRAY.variant(**changes)
    spec = with_array(vgg11_cifar10(), arr)
    for layer in spec.layers:
        assert layer.array == arr
        for f in dataclasses.fields(ArrayConfig):
            assert getattr(layer.array, f.name) == getattr(arr, f.name), f.name
    # the lowered matrix is array-independent; the tiling re-derives
    assert spec.layers[0].rows == vgg11_cifar10().layers[0].rows


TOPO_FIELDS = {
    "n_chips": st.sampled_from([1, 2, 4, 8]),
    "pes_per_chip": st.integers(1, 64),
    "arrays_per_pe": st.sampled_from([32, 64, 128]),
    "link_gbps": st.sampled_from([8.0, 32.0, 128.0]),
}


@st.composite
def topo_changes(draw):
    names = draw(
        st.lists(st.sampled_from(sorted(TOPO_FIELDS)), min_size=1, unique=True)
    )
    return {n: draw(TOPO_FIELDS[n]) for n in names}


@given(topo_changes(), array_changes())
@settings(max_examples=40, deadline=None)
def test_topology_variant_roundtrip(changes, arr_changes):
    base = FabricTopology(pes_per_chip=16)
    topo = base.variant(**changes, array=DEFAULT_ARRAY.variant(**arr_changes))
    for f in dataclasses.fields(FabricTopology):
        if f.name == "array":
            continue
        expect = changes.get(f.name, getattr(base, f.name))
        assert getattr(topo, f.name) == expect, f.name
    # derived capacities follow the varied fields
    assert topo.total_arrays == topo.n_chips * topo.pes_per_chip * topo.arrays_per_pe
    # the cost model re-derives from the varied ArrayConfig
    assert topo.hop_latency_cycles == topo.array.noc_hop_cycles * int(
        np.ceil(np.sqrt(topo.pes_per_chip))
    )
