"""Property-based tests on simulator invariants (hypothesis)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.cim import allocate, run_policy, simulate


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=96)


@pytest.fixture(scope="module")
def vgg64(profiled):
    return profiled("vgg11", n_images=1, sample_patches=64)


@given(st.integers(72, 400))
@settings(max_examples=12, deadline=None)
def test_utilization_bounded(vgg64, vgg_pes):
    spec, prof = vgg64
    r = run_policy(spec, prof, "blockwise", vgg_pes, n_images=8)
    assert np.all(r.layer_utilization > 0)
    assert np.all(r.layer_utilization <= 1.0 + 1e-9)


def test_busy_cycles_allocation_independent(vgg):
    """Total useful work is fixed; allocation only changes stalls."""
    spec, prof = vgg
    r1 = simulate(spec, prof, allocate(spec, prof, "weight_based", 144), 16)
    r2 = simulate(spec, prof, allocate(spec, prof, "blockwise", 144), 16)
    # utilization * arrays * T = busy cycles; compare busy totals per layer
    busy1 = r1.layer_utilization * r1.total_cycles
    busy2 = r2.layer_utilization * r2.total_cycles
    # blockwise uses its arrays more: higher utilization, lower T
    assert r2.total_cycles <= r1.total_cycles
    assert r2.mean_utilization >= r1.mean_utilization


def test_throughput_scales_linearly_in_images(vgg):
    spec, prof = vgg
    a = allocate(spec, prof, "blockwise", 144)
    t16 = simulate(spec, prof, a, n_images=16).total_cycles
    t64 = simulate(spec, prof, a, n_images=64).total_cycles
    assert t64 == pytest.approx(4 * t16, rel=0.05)


def test_bottleneck_layer_determines_throughput(vgg):
    spec, prof = vgg
    a = allocate(spec, prof, "blockwise", 144)
    r = simulate(spec, prof, a, n_images=16)
    assert r.total_cycles == pytest.approx(r.layer_cycles.max())


@given(st.sampled_from(["baseline", "weight_based", "perf_layerwise", "blockwise"]))
@settings(max_examples=8, deadline=None)
def test_more_arrays_never_hurt(vgg64, policy):
    spec, prof = vgg64
    small = run_policy(spec, prof, policy, 100, n_images=8).images_per_sec
    big = run_policy(spec, prof, policy, 200, n_images=8).images_per_sec
    assert big >= small * 0.999
