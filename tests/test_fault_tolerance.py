"""Fault tolerance: checkpoint/restart, failure replay, straggler detection,
elastic re-mesh."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distrib.context import set_mesh
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.fault import FaultInjector, RunnerConfig, TrainRunner
from repro.train.step import make_train_step


@pytest.fixture()
def tiny_setup():
    cfg = get_config("glm4-9b", smoke=True)
    set_mesh(None)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    opt_state = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    return cfg, params, opt_state, step_fn, data


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    _, params, opt_state, _, _ = tiny_setup
    tree = {"params": params, "opt": opt_state}
    save_checkpoint(str(tmp_path), 7, tree, config_fingerprint="fp1")
    restored, manifest = restore_checkpoint(str(tmp_path), tree, config_fingerprint="fp1")
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_fingerprint_mismatch(tmp_path, tiny_setup):
    _, params, _, _, _ = tiny_setup
    save_checkpoint(str(tmp_path), 1, {"p": params}, config_fingerprint="A")
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"p": params}, config_fingerprint="B")


def test_checkpoint_gc_keeps_last(tmp_path):
    tree = {"x": jnp.ones((4,))}
    for s in range(6):
        save_checkpoint(str(tmp_path), s, tree, keep_last=2)
    assert list_steps(str(tmp_path)) == [4, 5]
    assert latest_step(str(tmp_path)) == 5


# -------------------------------------------------------------- data pipeline
def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab=100, seq_len=64, global_batch=8, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # shards partition deterministically and differ from each other
    s0 = d1.batch(5, shard=0, n_shards=2)
    s1 = d1.batch(5, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 64)
    assert not np.array_equal(np.asarray(s0["tokens"]), np.asarray(s1["tokens"]))


def test_data_has_learnable_structure(tiny_setup):
    """Loss must DROP on the synthetic stream (motifs are learnable)."""
    cfg, params, opt_state, step_fn, data = tiny_setup
    losses = []
    for s in range(8):
        params, opt_state, m = step_fn(params, opt_state, data.batch(s))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


# ---------------------------------------------------------------- fault runs
def test_run_survives_injected_failures(tmp_path, tiny_setup):
    cfg, params, opt_state, step_fn, data = tiny_setup
    inj = FaultInjector(fail_at={4: 1, 7: 2})
    runner = TrainRunner(
        RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_retries_per_step=3),
        step_fn,
        lambda s: data.batch(s),
        fault_hook=inj,
    )
    params, opt_state = runner.run(params, opt_state, n_steps=10)
    assert runner.restores >= 3  # every injected failure went through restore
    assert latest_step(str(tmp_path)) == 10
    # every step 0..9 completed at least once
    assert {h.step for h in runner.history} == set(range(10))


def test_failed_run_matches_clean_run(tmp_path, tiny_setup):
    """Restore + deterministic data replay ==> identical final params."""
    cfg, params, opt_state, step_fn, data = tiny_setup
    clean = TrainRunner(
        RunnerConfig(ckpt_dir=str(tmp_path / "clean"), ckpt_every=3),
        step_fn,
        lambda s: data.batch(s),
    )
    p_clean, _ = clean.run(params, opt_state, n_steps=9)

    faulty = TrainRunner(
        RunnerConfig(ckpt_dir=str(tmp_path / "faulty"), ckpt_every=3),
        step_fn,
        lambda s: data.batch(s),
        fault_hook=FaultInjector(fail_at={5: 1, 8: 1}),
    )
    p_faulty, _ = faulty.run(params, opt_state, n_steps=9)
    assert faulty.restores >= 2
    for a, b in zip(jax.tree.leaves(p_clean), jax.tree.leaves(p_faulty)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_straggler_detection(tmp_path, tiny_setup):
    cfg, params, opt_state, step_fn, data = tiny_setup
    # warm the jit cache so the compile doesn't dominate the EWMA baseline
    step_fn(params, opt_state, data.batch(0))
    seen = []
    runner = TrainRunner(
        RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=50, straggler_factor=3.0),
        step_fn,
        lambda s: data.batch(s),
        fault_hook=FaultInjector(slow_at={6: 1.0}),
        on_straggler=lambda st: seen.append(st.step),
    )
    runner.run(params, opt_state, n_steps=8)
    assert 6 in seen


def test_elastic_remesh_restore(tmp_path, tiny_setup):
    """Save under one mesh, restore + re-jit under another (1x1 <-> 2x1
    host-device degenerate case: structure-level elasticity)."""
    cfg, params, opt_state, step_fn, data = tiny_setup
    params, opt_state, _ = step_fn(params, opt_state, data.batch(0))
    save_checkpoint(
        str(tmp_path), 1, {"params": params, "opt": opt_state}, mesh_shape=(1, 1)
    )
    # restore against abstract ShapeDtypeStructs (as a fresh process would)
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), {"params": params, "opt": opt_state}
    )
    restored, manifest = restore_checkpoint(str(tmp_path), abstract)
    assert manifest["mesh_shape"] == [1, 1]
    p2, o2, m = step_fn(restored["params"], restored["opt"], data.batch(1))
    assert np.isfinite(float(m["loss"]))