"""Slot engine + continuous-batching scheduler tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distrib.context import set_mesh
from repro.models import forward, init_cache, init_params
from repro.serve.engine import (
    init_slot_state,
    prefill_slot,
    reset_slots,
    slot_decode_step,
)
from repro.serve.scheduler import (
    WorkloadConfig,
    sample_lengths,
    simulate_continuous,
    simulate_static,
)


@pytest.fixture(scope="module")
def setup():
    set_mesh(None)
    cfg = get_config("glm4-9b", smoke=True).with_(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_slot_decode_matches_batch_decode(setup):
    """All slots aligned => identical to the standard decode path."""
    cfg, params = setup
    b, steps = 3, 6
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, steps), 0, cfg.vocab)

    # reference: standard cache path
    cache = init_cache(cfg, b, max_seq=16, dtype=jnp.float32)
    ref = []
    for t in range(steps):
        lg, cache = forward(params, cfg, toks[:, t : t + 1], cache=cache)
        ref.append(lg[:, 0])

    # slot engine
    state = init_slot_state(cfg, b, max_seq=16, dtype=jnp.float32)
    got = []
    for t in range(steps):
        lg, state = slot_decode_step(params, cfg, state, toks[:, t])
        got.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(ref, 1)), np.asarray(jnp.stack(got, 1)), rtol=2e-3, atol=2e-3
    )


def test_slot_isolation_on_reset(setup):
    """Resetting one slot must not change another slot's logits — the
    engine-level version of the paper's 'independent blocks' requirement."""
    cfg, params = setup
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (2, 5), 0, cfg.vocab)

    # run A: both slots together, 5 steps
    state = init_slot_state(cfg, 2, max_seq=16, dtype=jnp.float32)
    for t in range(4):
        _, state = slot_decode_step(params, cfg, state, toks[:, t])
    # reset slot 1, keep slot 0; decode one more step
    state = reset_slots(state, jnp.array([False, True]))
    assert int(state["lens"][0]) == 4 and int(state["lens"][1]) == 0
    lg, _ = slot_decode_step(params, cfg, state, toks[:, 4])

    # run B: slot 0 alone, same history
    solo = init_slot_state(cfg, 1, max_seq=16, dtype=jnp.float32)
    for t in range(4):
        _, solo = slot_decode_step(params, cfg, solo, toks[:1, t])
    lg_solo, _ = slot_decode_step(params, cfg, solo, toks[:1, 4])
    np.testing.assert_allclose(
        np.asarray(lg[0]), np.asarray(lg_solo[0]), rtol=2e-3, atol=2e-3
    )


def test_stale_cache_masked_after_reset(setup):
    """A refilled slot must not attend to the previous request's kv."""
    cfg, params = setup
    key = jax.random.PRNGKey(3)
    t1 = jax.random.randint(key, (1, 6), 0, cfg.vocab)
    t2 = jax.random.randint(jax.random.fold_in(key, 1), (1, 3), 0, cfg.vocab)

    state = init_slot_state(cfg, 1, max_seq=16, dtype=jnp.float32)
    for t in range(6):
        _, state = slot_decode_step(params, cfg, state, t1[:, t])
    state = reset_slots(state, jnp.array([True]))
    outs = []
    for t in range(3):
        lg, state = slot_decode_step(params, cfg, state, t2[:, t])
        outs.append(lg)

    fresh = init_slot_state(cfg, 1, max_seq=16, dtype=jnp.float32)
    outs_fresh = []
    for t in range(3):
        lg, fresh = slot_decode_step(params, cfg, fresh, t2[:, t])
        outs_fresh.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs)), np.asarray(jnp.stack(outs_fresh)), rtol=2e-3, atol=2e-3
    )


def test_prefill_preserves_untouched_slot_lens(setup):
    """reset_slots + prefill_slot must leave unmasked slots' lens alone."""
    cfg, params = setup
    key = jax.random.PRNGKey(4)
    toks = jax.random.randint(key, (2, 3), 0, cfg.vocab)
    prompt = jax.random.randint(jax.random.fold_in(key, 1), (2, 4), 0, cfg.vocab)

    state = init_slot_state(cfg, 2, max_seq=16, dtype=jnp.float32)
    for t in range(3):
        _, state = slot_decode_step(params, cfg, state, toks[:, t])
    state = reset_slots(state, jnp.array([False, True]))
    logits, state = prefill_slot(params, cfg, state, prompt, jnp.array([False, True]))
    assert logits.shape[0] == 2
    # slot 0 untouched: len still 3; slot 1 refilled: len == prompt length
    assert int(state["lens"][0]) == 3
    assert int(state["lens"][1]) == 4


def test_masked_attention_ignores_stale_rows_after_prefill(setup):
    """After a masked prefill clobbers cache rows beyond a kept slot's len,
    the kept slot's next decode must still match a solo run — the per-sample
    valid mask (and the scatter at lens[b]) hide every stale row."""
    cfg, params = setup
    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (2, 4), 0, cfg.vocab)
    prompt = jax.random.randint(jax.random.fold_in(key, 1), (2, 5), 0, cfg.vocab)
    nxt = jax.random.randint(jax.random.fold_in(key, 2), (2,), 0, cfg.vocab)

    state = init_slot_state(cfg, 2, max_seq=16, dtype=jnp.float32)
    for t in range(4):
        _, state = slot_decode_step(params, cfg, state, toks[:, t])
    state = reset_slots(state, jnp.array([False, True]))
    # prefill writes at slot 0's positions 4..8 too (demo-engine tradeoff) —
    # those rows are stale for slot 0, whose len snaps back to 4
    _, state = prefill_slot(params, cfg, state, prompt, jnp.array([False, True]))
    lg, _ = slot_decode_step(params, cfg, state, nxt)

    solo = init_slot_state(cfg, 1, max_seq=16, dtype=jnp.float32)
    for t in range(4):
        _, solo = slot_decode_step(params, cfg, solo, toks[:1, t])
    lg_solo, _ = slot_decode_step(params, cfg, solo, nxt[:1])
    np.testing.assert_allclose(
        np.asarray(lg[0]), np.asarray(lg_solo[0]), rtol=2e-3, atol=2e-3
    )


def test_prefill_logits_match_stepwise_decode(setup):
    """prefill_slot is just repeated slot_decode_step: last logits agree."""
    cfg, params = setup
    key = jax.random.PRNGKey(6)
    prompt = jax.random.randint(key, (1, 5), 0, cfg.vocab)

    state = init_slot_state(cfg, 1, max_seq=16, dtype=jnp.float32)
    lg_pref, _ = prefill_slot(params, cfg, state, prompt, jnp.array([True]))

    state2 = init_slot_state(cfg, 1, max_seq=16, dtype=jnp.float32)
    for t in range(5):
        lg_step, state2 = slot_decode_step(params, cfg, state2, prompt[:, t])
    np.testing.assert_allclose(
        np.asarray(lg_pref), np.asarray(lg_step), rtol=2e-3, atol=2e-3
    )


# ---------------------------------------------------------------- scheduler
def test_continuous_beats_static_on_skew():
    lens = sample_lengths(WorkloadConfig(n_requests=512, sigma=1.0))
    st = simulate_static(lens, n_slots=16)
    ct = simulate_continuous(lens, n_slots=16)
    assert ct.utilization > st.utilization * 1.3
    assert ct.total_steps < st.total_steps
    # identical useful work
    assert ct.slot_steps_used == st.slot_steps_used == int(lens.sum())


def test_equal_lengths_no_gain():
    """No skew -> no barrier -> static == continuous (sanity)."""
    lens = np.full(128, 64, dtype=np.int64)
    st = simulate_static(lens, n_slots=16)
    ct = simulate_continuous(lens, n_slots=16)
    assert st.utilization == pytest.approx(1.0)
    assert ct.total_steps == st.total_steps


def test_utilization_bounds():
    lens = sample_lengths(WorkloadConfig(n_requests=100, sigma=0.5, seed=7))
    for n_slots in (4, 16, 50):
        for sim in (simulate_static, simulate_continuous):
            s = sim(lens, n_slots)
            assert 0 < s.utilization <= 1.0 + 1e-9


# ----------------------------------------------- fabric co-scheduling loop
def test_fabric_slot_plan_grants():
    from repro.serve.scheduler import fabric_slot_plan

    slots = fabric_slot_plan([1e6, 4e6, 0.0], slo_cycles=2e6, n_slots=8)
    np.testing.assert_array_equal(slots, [8, 4, 8])  # inside SLO / 2x over / idle
    assert fabric_slot_plan([1e9], 1e3, 8, min_slots=2)[0] == 2  # floor
    with pytest.raises(ValueError):
        fabric_slot_plan([1.0], 0.0, 8)
    with pytest.raises(ValueError):
        fabric_slot_plan([1.0], 1.0, 8, min_slots=9)


def test_segmented_replay_drives_dormant_slot_lifecycle(setup, profiled):
    """End-to-end co-scheduling smoke: a segmented fleet replay produces
    per-allocation p99s, ``fabric_slot_plan`` converts them to decode slot
    budgets, the analytic scheduler runs at that budget, and the real slot
    engine parks the revoked slots dormant — without perturbing the live
    ones (the dormant-slot machinery under a fabric-driven mask)."""
    from repro.core.cim import allocate, simulate
    from repro.core.cim.simulate import CLOCK_HZ
    from repro.fabric import (
        PoissonOpen,
        VirtualTimeFabric,
        arrival_times,
        run_trace_segments,
        segment_growth_plan,
    )
    from repro.serve.scheduler import fabric_slot_plan

    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    bw = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, bw, n_images=64).images_per_sec
    vt = VirtualTimeFabric(spec, prof)
    plan = segment_growth_plan(spec, prof, bw, budgets=[64])
    # two candidate allocations: static small vs grown-at-boundary
    times = arrival_times(
        PoissonOpen(n_requests=30, rate_per_cycle=0.7 * cap / CLOCK_HZ, seed=2)
    )
    bound = [float(times[14]) + 0.5]
    res = run_trace_segments(
        vt, [[bw, plan[0]], [bw, plan[1]]], times, bound, seed=2,
        engine="numpy", window=4, pad_to=8,
    )
    p99 = res.p99
    n_slots = 4
    slots = fabric_slot_plan(p99, slo_cycles=float(np.median(p99)), n_slots=n_slots)
    assert slots.min() >= 1 and slots.max() <= n_slots
    assert slots[int(np.argmax(p99))] <= slots[int(np.argmin(p99))]

    # the granted budget drives batch formation for the worst allocation
    lens = sample_lengths(WorkloadConfig(n_requests=32, mean_len=8.0, seed=3))
    stats = simulate_continuous(lens, n_slots=int(slots.min()))
    assert stats.slot_steps_used == int(lens.sum())

    # slot engine honors the grant: slots >= grant are parked dormant
    cfg, params = setup
    grant = max(int(slots.min()), 1)
    key = jax.random.PRNGKey(9)
    toks = jax.random.randint(key, (n_slots, 4), 0, cfg.vocab)
    state = init_slot_state(cfg, n_slots, max_seq=16, dtype=jnp.float32)
    for t in range(3):
        _, state = slot_decode_step(params, cfg, state, toks[:, t])
    dormant = jnp.arange(n_slots) >= grant
    state = reset_slots(state, dormant)
    assert np.all(np.asarray(state["lens"])[grant:] == 0)  # parked
    assert np.all(np.asarray(state["lens"])[:grant] == 3)  # untouched
    lg, _ = slot_decode_step(params, cfg, state, toks[:, 3])
    solo = init_slot_state(cfg, grant, max_seq=16, dtype=jnp.float32)
    for t in range(4):
        lg_solo, solo = slot_decode_step(params, cfg, solo, toks[:grant, t])
    np.testing.assert_allclose(
        np.asarray(lg[:grant]), np.asarray(lg_solo), rtol=2e-3, atol=2e-3
    )
