"""FabricSim vs the analytic model on VGG11 + tail-latency and drift
scenarios (VGG11 keeps the event counts small; the ResNet18 acceptance runs
live in test_fabric_resnet18.py)."""

import numpy as np
import pytest

from repro.core.cim import allocate, simulate
from repro.core.cim.simulate import ARRAYS_PER_PE, CLOCK_HZ, Policy
from repro.fabric import (
    ClosedLoop,
    DriftConfig,
    FabricSim,
    OnlineReallocator,
    PoissonOpen,
    TraceReplay,
    shift_profile,
)

POLICIES = ("baseline", "weight_based", "perf_layerwise", "weight_blockflow", "blockwise")


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=128)


@pytest.mark.parametrize("policy", POLICIES)
def test_closed_loop_matches_analytic(vgg, policy):
    spec, prof = vgg
    alloc = allocate(spec, prof, policy, spec.min_pes() * 2)
    ana = simulate(spec, prof, alloc, n_images=64)
    res = FabricSim(spec, prof, alloc, seed=1).run(ClosedLoop(n_requests=40, concurrency=16))
    assert res.images_per_sec == pytest.approx(ana.images_per_sec, rel=0.10)


def test_utilization_and_latency_sane(vgg):
    spec, prof = vgg
    alloc = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    res = FabricSim(spec, prof, alloc, seed=1).run(ClosedLoop(n_requests=40, concurrency=16))
    u = res.layer_utilization
    assert u.shape == (len(spec.layers),)
    assert np.all(u > 0) and np.all(u <= 1.0 + 1e-9)
    lat = res.latencies
    assert np.all(lat > 0)
    # closed loop: completions cover all requests, in finite time
    assert res.completions.size == 40 and np.all(res.completions > 0)


def test_blockwise_beats_weight_based_p99(vgg):
    """Acceptance: same open-loop Poisson load, strictly better tail."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    wb = allocate(spec, prof, "weight_based", pes)
    bw = allocate(spec, prof, "blockwise", pes)
    cap_wb = simulate(spec, prof, wb, n_images=64).images_per_sec
    proc = PoissonOpen(n_requests=300, rate_per_cycle=0.7 * cap_wb / CLOCK_HZ, seed=5)
    r_wb = FabricSim(spec, prof, wb, seed=3).run(proc)
    r_bw = FabricSim(spec, prof, bw, seed=3).run(proc)
    assert r_bw.latency.p99 < r_wb.latency.p99
    assert r_bw.latency.p50 < r_wb.latency.p50


def test_open_loop_overload_queues_grow(vgg):
    """Above capacity the backlog (and so latency) must keep climbing —
    an open-loop property the analytic model cannot express."""
    spec, prof = vgg
    alloc = allocate(spec, prof, "blockwise", spec.min_pes())
    cap = simulate(spec, prof, alloc, n_images=64).images_per_sec
    proc = PoissonOpen(n_requests=120, rate_per_cycle=1.5 * cap / CLOCK_HZ, seed=7)
    res = FabricSim(spec, prof, alloc, seed=4).run(proc)
    lat = res.latencies
    first, last = lat[:30].mean(), lat[-30:].mean()
    assert last > 3 * first


def test_trace_replay_bursts_hurt_tail(vgg):
    """Same mean rate, bursty vs evenly spaced: bursts must show up in p99."""
    spec, prof = vgg
    alloc = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    cap = simulate(spec, prof, alloc, n_images=64).images_per_sec
    gap = CLOCK_HZ / (0.6 * cap)
    n = 128
    even = np.arange(1, n + 1) * gap
    # same span, arrivals packed in bursts of 16
    bursts = (np.repeat(np.arange(1, n // 16 + 1) * 16 * gap, 16)
              + np.tile(np.arange(16.0), n // 16))
    r_even = FabricSim(spec, prof, alloc, seed=6).run(TraceReplay(even))
    r_burst = FabricSim(spec, prof, alloc, seed=6).run(TraceReplay(bursts))
    assert r_burst.latency.p99 > r_even.latency.p99


def test_drift_reallocation_recovers_throughput(vgg):
    """Acceptance: after a distribution shift the online re-allocator must
    recover >= half of the throughput a clairvoyant re-allocation gets back."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    free = pes * ARRAYS_PER_PE - spec.n_arrays
    reserve = 0.4
    alloc0 = allocate(spec, prof, "blockwise", pes, free_budget=free * (1 - reserve))
    shifted = shift_profile(prof, {4: 1.8, 5: 1.8, 6: 1.8})
    cl = ClosedLoop(n_requests=120, concurrency=24)

    stale = FabricSim(spec, prof, alloc0, seed=2, live_prof=shifted).run(cl)
    rl = OnlineReallocator(spec, prof, reserve_arrays=free * reserve, cfg=DriftConfig())
    online = FabricSim(spec, prof, alloc0, seed=2, live_prof=shifted, reallocator=rl).run(cl)
    oracle_alloc = allocate(spec, shifted, "blockwise", pes)
    oracle = FabricSim(spec, shifted, oracle_alloc, seed=2).run(cl)

    ts, to, torc = stale.images_per_sec, online.images_per_sec, oracle.images_per_sec
    assert torc > ts  # the shift really hurt the stale allocation
    recovery = (to - ts) / (torc - ts)
    assert recovery >= 0.5, f"recovered only {recovery:.2f} of lost throughput"
    # the re-allocation is visible, charged, and paid from the reserve
    assert len(online.reallocations) >= 1
    ev = online.reallocations[0]
    assert ev.arrays_added > 0 and ev.stall_cycles > 0 and ev.divergence > 0
    assert rl.budget >= 0


def test_drift_monitor_quiet_without_drift(vgg):
    """No shift -> no reallocation (EWMA stays inside the threshold)."""
    spec, prof = vgg
    pes = spec.min_pes() * 2
    free = pes * ARRAYS_PER_PE - spec.n_arrays
    alloc0 = allocate(spec, prof, "blockwise", pes, free_budget=free * 0.6)
    rl = OnlineReallocator(spec, prof, reserve_arrays=free * 0.4, cfg=DriftConfig())
    res = FabricSim(spec, prof, alloc0, seed=2, reallocator=rl).run(
        ClosedLoop(n_requests=60, concurrency=16)
    )
    assert res.reallocations == []
    assert rl.divergence < DriftConfig().threshold


def test_growth_never_shrinks_replicas(vgg):
    spec, prof = vgg
    pes = spec.min_pes() * 2
    free = pes * ARRAYS_PER_PE - spec.n_arrays
    alloc0 = allocate(spec, prof, "blockwise", pes, free_budget=free * 0.6)
    before = np.concatenate(alloc0.block_dups)
    rl = OnlineReallocator(spec, prof, reserve_arrays=free * 0.4, cfg=DriftConfig())
    sim = FabricSim(
        spec, prof, alloc0, seed=2,
        live_prof=shift_profile(prof, {4: 1.8, 5: 1.8, 6: 1.8}),
        reallocator=rl,
    )
    sim.run(ClosedLoop(n_requests=80, concurrency=16))
    after = sim.current_block_dups()
    assert np.all(after >= before)
