"""Telemetry layer: recorder semantics, zero-overhead-off guarantees, and
the hard correctness bar from the issue — instrumented runs are bit-identical
to uninstrumented ones (pinned by the golden fabric fixtures) and the jit
virtual-time accumulators reconcile with the event engine's counters."""

import json
import pathlib

import numpy as np
import pytest

from repro.core.cim import FabricTopology, allocate, allocate_placed
from repro.core.cim.simulate import CLOCK_HZ
from repro.fabric import (
    NULL_TELEMETRY,
    FabricSim,
    PoissonOpen,
    Telemetry,
    VirtualTimeFabric,
    get_telemetry,
    set_telemetry,
    telemetry_session,
)
from repro.obs import utilization_report

GOLDEN = pathlib.Path(__file__).parent / "golden"
RTOL = 1e-9


# ------------------------------------------------------------ recorder unit
def test_counters_gauges_histograms():
    t = Telemetry()
    t.count("jobs")
    t.count("jobs", 4)
    t.gauge("depth", 3.0)
    t.gauge("depth", 7.0)  # last write wins
    for v in (1.0, 2.0, 3.0, 4.0):
        t.observe("lat", v)
    snap = t.snapshot()
    assert snap["counters"]["jobs"] == 5
    assert snap["gauges"]["depth"] == 7.0
    h = snap["histograms"]["lat"]
    assert h["count"] == 4 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["mean"] == pytest.approx(2.5)


def test_spans_and_timed():
    t = Telemetry()
    t.span("load", 1.0, 3.0, layer=2)
    with t.timed("work", tag="x"):
        pass
    snap = t.snapshot()
    names = [s["name"] for s in snap["spans"]]
    assert names == ["load", "work"]
    assert snap["spans"][0]["layer"] == 2
    assert "work.s" in snap["histograms"]  # timed() also feeds a histogram
    t.reset()
    assert t.snapshot() == {
        "counters": {},
        "gauges": {},
        "histograms": {},
        "spans": [],
    }


def test_null_telemetry_records_nothing():
    n = NULL_TELEMETRY
    n.count("x")
    n.gauge("x", 1.0)
    n.observe("x", 1.0)
    n.span("x", 0.0, 1.0)
    with n.timed("x"):
        pass
    assert not n.enabled
    assert n.snapshot() == {
        "counters": {},
        "gauges": {},
        "histograms": {},
        "spans": [],
    }


def test_session_installs_and_restores_global():
    assert get_telemetry() is NULL_TELEMETRY
    with telemetry_session() as t:
        assert get_telemetry() is t
        t.count("inside")
        with telemetry_session() as inner:  # nests; inner shadows outer
            assert get_telemetry() is inner
        assert get_telemetry() is t
    assert get_telemetry() is NULL_TELEMETRY
    assert t.snapshot()["counters"] == {"inside": 1}


def test_set_telemetry_none_resets_to_null():
    t = Telemetry()
    assert set_telemetry(t) is t
    try:
        assert get_telemetry() is t
    finally:
        set_telemetry(None)
    assert get_telemetry() is NULL_TELEMETRY


# ------------------------------------------- golden bit-identity (stats on)
@pytest.fixture(scope="module")
def vgg_golden(profiled):
    g = json.loads((GOLDEN / "vgg11_fabric_scalar.json").read_text())
    spec, prof = profiled("vgg11", **g["profile_params"])
    return spec, prof, g


def test_stats_on_matches_golden_bit_for_bit(vgg_golden):
    """stats=True must not perturb a single float: percentiles and
    completion times still equal the pre-telemetry pinned fixture exactly."""
    spec, prof, g = vgg_golden
    topo = FabricTopology.single_chip(g["results"][0]["n_pes"])
    for rec in g["results"]:
        kw = (
            {"offered_ips": rec["offered_ips"]}
            if rec["policy"] == "latency_aware"
            else {}
        )
        placed = allocate_placed(spec, prof, rec["policy"], topo, **kw)
        proc = PoissonOpen(
            g["n_requests"], rec["offered_ips"] / CLOCK_HZ, seed=g["arrival_seed"]
        )
        r = FabricSim(
            spec, prof, placed.allocation, seed=g["service_seed"],
            placement=placed.placement, stats=True,
        ).run(proc)
        pct = np.percentile(r.latencies, [50.0, 95.0, 99.0])
        assert pct.tolist() == rec["percentiles"], rec["policy"]
        assert float(r.completions.sum()) == rec["completions_sum"]
        assert r.completions[:5].tolist() == rec["completions_head"]
        assert r.completions[-5:].tolist() == rec["completions_tail"]
        assert r.stats is not None


# ----------------------------------------- event <-> vtime reconciliation
def _reconcile(spec, prof, policies, pes, n_req=80, load=0.7):
    from repro.core.cim import simulate

    allocs = [allocate(spec, prof, p, pes) for p in policies]
    cap = simulate(spec, prof, allocs[-1], n_images=64).images_per_sec
    proc = PoissonOpen(n_requests=n_req, rate_per_cycle=load * cap / CLOCK_HZ, seed=5)
    ev = [FabricSim(spec, prof, a, seed=3, stats=True).run(proc) for a in allocs]
    vt = VirtualTimeFabric(spec, prof)
    von = vt.run_batch(allocs, proc, seed=3, collect_stats=True)
    voff = vt.run_batch(allocs, proc, seed=3)
    # collect_stats must not change the kernel's answers...
    np.testing.assert_array_equal(voff.completions, von.completions)
    for i, r in enumerate(ev):
        # ...the engines stay bit-identical with telemetry on...
        np.testing.assert_array_equal(r.completions, von.completions[i])
        # ...and the in-kernel accumulators equal the event counters (fp
        # tolerance: scalar += vs vectorized sums accumulate in different
        # orders — documented in ISSUE acceptance)
        np.testing.assert_allclose(
            r.stats.layer_service, von.layer_busy[i], rtol=RTOL
        )
        np.testing.assert_allclose(
            r.stats.layer_queue_wait, von.layer_wait[i], rtol=RTOL, atol=1e-6
        )
    return ev


def test_vtime_accumulators_reconcile_vgg11(profiled):
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    _reconcile(spec, prof, ["weight_based", "blockwise"], spec.min_pes() * 2)


@pytest.mark.slow
def test_vtime_accumulators_reconcile_resnet18(profiled):
    spec, prof = profiled("resnet18", n_images=1, sample_patches=64)
    _reconcile(spec, prof, ["weight_based", "blockwise"], spec.min_pes() * 2)


# ----------------------------------------------------- stats semantics
def test_fabric_stats_invariants(profiled):
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    alloc = allocate(spec, prof, "blockwise", spec.min_pes() * 2)
    proc = PoissonOpen(n_requests=60, rate_per_cycle=2000.0 / CLOCK_HZ, seed=5)
    r = FabricSim(spec, prof, alloc, seed=3, stats=True).run(proc)
    st = r.stats
    L = len(spec.layers)
    assert st.layer_service.shape == (L,)
    assert st.layer_jobs.sum() > 0
    assert np.all(st.layer_queue_wait >= -1e-6)
    # replica lanes partition the pool's service cycles
    for li in range(L):
        lanes = np.concatenate([np.asarray(b) for b in st.replica_busy[li]])
        assert lanes.sum() == pytest.approx(st.layer_service[li], rel=1e-9)
    imb = st.replica_imbalance()
    assert imb.shape == (L,) and np.all(imb >= 1.0 - 1e-12)
    # requests traverse stages in order
    assert np.all(st.stage_exit >= st.stage_entry)
    assert np.all(np.diff(st.stage_entry, axis=1) >= 0)


def test_utilization_report_partitions_capacity(profiled):
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    alloc = allocate(spec, prof, "weight_based", spec.min_pes() * 2)
    proc = PoissonOpen(n_requests=60, rate_per_cycle=2000.0 / CLOCK_HZ, seed=5)
    r = FabricSim(spec, prof, alloc, seed=3, stats=True).run(proc)
    rep = utilization_report(r)
    total = rep.duty_cycle + rep.barrier_frac + rep.reprogram_frac + rep.starved_frac
    np.testing.assert_allclose(total, 1.0, atol=1e-9)
    assert np.all(rep.duty_cycle >= 0) and np.all(rep.duty_cycle <= 1 + 1e-12)
    assert 0.0 < rep.mean_duty_cycle <= 1.0
    txt = rep.format()
    assert "duty" in txt and str(len(spec.layers) - 1) in txt
    js = json.loads(json.dumps(rep.to_json()))  # round-trips through JSON
    assert js["n_requests"] == 60


def test_utilization_report_requires_stats(profiled):
    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    alloc = allocate(spec, prof, "weight_based", spec.min_pes() * 2)
    proc = PoissonOpen(n_requests=10, rate_per_cycle=2000.0 / CLOCK_HZ, seed=5)
    r = FabricSim(spec, prof, alloc, seed=3).run(proc)
    with pytest.raises(ValueError, match="stats"):
        utilization_report(r)


# ------------------------------------------------------- allocation audit
def test_allocation_audit_traces_greedy_grants(profiled):
    from repro.obs import AllocationAudit

    spec, prof = profiled("vgg11", n_images=1, sample_patches=64)
    pes = spec.min_pes() * 2
    audit = AllocationAudit()
    a = allocate(spec, prof, "perf_layerwise", pes, audit=audit)
    plain = allocate(spec, prof, "perf_layerwise", pes)
    # auditing must not steer the allocator
    np.testing.assert_array_equal(a.layer_dups, plain.layer_dups)
    assert len(audit.grants) > 0
    assert audit.stop_reason == "budget"
    for e in audit.grants:
        assert e.latency_after < e.latency_before  # each grant helps its unit
        assert e.remaining >= 0
    # grants per unit reconcile with the final replica counts (the first
    # replica per layer is seeded before the greedy loop)
    per_unit = audit.summary()["grants_per_unit"]
    for li, d in enumerate(a.layer_dups.tolist()):
        assert per_unit.get(li, 0) == d - 1
    js = json.loads(json.dumps(audit.to_json()))
    assert len(js) == len(audit.entries)
