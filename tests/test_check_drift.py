"""`benchmarks/check_drift.py` CLI error handling: a missing or malformed
BENCH_*.json must produce a single-line error on stderr and exit code 2 —
never a traceback (the nightly log should say what to do, not where Python
died)."""

import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "check_drift.py"), *args],
        cwd=cwd,
        capture_output=True,
        text=True,
        timeout=120,
    )


def test_missing_bench_file_is_one_line_error(tmp_path):
    r = _run("--root", str(tmp_path), "no_such_mode")
    assert r.returncode == 2
    lines = [ln for ln in r.stderr.splitlines() if ln.strip()]
    assert len(lines) == 1
    assert lines[0].startswith("error:")
    assert "BENCH_no_such_mode.json" in lines[0]
    assert "benchmarks.run --json no_such_mode" in lines[0]  # says what to do
    assert "Traceback" not in r.stderr


def test_malformed_bench_file_is_one_line_error(tmp_path):
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    r = _run("--root", str(tmp_path), "broken")
    assert r.returncode == 2
    lines = [ln for ln in r.stderr.splitlines() if ln.strip()]
    assert len(lines) == 1
    assert lines[0].startswith("error:")
    assert "BENCH_broken.json" in lines[0]
    assert "Traceback" not in r.stderr


def test_valid_file_without_baseline_passes(tmp_path):
    doc = {
        "mode": "fake",
        "rows": [{"name": "fake_row", "us_per_call": 1.0, "derived": "speedup=2.00x"}],
    }
    (tmp_path / "BENCH_fake.json").write_text(json.dumps(doc))
    r = _run("--root", str(tmp_path), "fake")
    assert r.returncode == 0, r.stderr
    assert "no baseline" in r.stdout


def test_default_glob_still_checks_repo_files():
    """Without positional modes the committed BENCH files are compared to
    HEAD — the committed numbers must never regress against themselves."""
    r = _run()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "checked" in r.stdout
