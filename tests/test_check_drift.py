"""`benchmarks/check_drift.py` CLI error handling: a missing or malformed
BENCH_*.json must produce a single-line error on stderr and exit code 2 —
never a traceback (the nightly log should say what to do, not where Python
died).  Plus the like-for-like guard: rows stamped ``configs=<n>`` only
have their speedup ratios compared when baseline and fresh agree on the
grid size (a resized grid skips with a WARN, never silently passes or
spuriously fails)."""

import importlib.util
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def _load_check_drift():
    spec = importlib.util.spec_from_file_location(
        "check_drift_under_test", REPO / "benchmarks" / "check_drift.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _run(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "check_drift.py"), *args],
        cwd=cwd,
        capture_output=True,
        text=True,
        timeout=120,
    )


def test_missing_bench_file_is_one_line_error(tmp_path):
    r = _run("--root", str(tmp_path), "no_such_mode")
    assert r.returncode == 2
    lines = [ln for ln in r.stderr.splitlines() if ln.strip()]
    assert len(lines) == 1
    assert lines[0].startswith("error:")
    assert "BENCH_no_such_mode.json" in lines[0]
    assert "benchmarks.run --json no_such_mode" in lines[0]  # says what to do
    assert "Traceback" not in r.stderr


def test_malformed_bench_file_is_one_line_error(tmp_path):
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    r = _run("--root", str(tmp_path), "broken")
    assert r.returncode == 2
    lines = [ln for ln in r.stderr.splitlines() if ln.strip()]
    assert len(lines) == 1
    assert lines[0].startswith("error:")
    assert "BENCH_broken.json" in lines[0]
    assert "Traceback" not in r.stderr


def test_valid_file_without_baseline_passes(tmp_path):
    doc = {
        "mode": "fake",
        "rows": [{"name": "fake_row", "us_per_call": 1.0, "derived": "speedup=2.00x"}],
    }
    (tmp_path / "BENCH_fake.json").write_text(json.dumps(doc))
    r = _run("--root", str(tmp_path), "fake")
    assert r.returncode == 0, r.stderr
    assert "no baseline" in r.stdout


def test_required_headline_keys_enforced(tmp_path):
    """dse_fused must report BOTH acceptance ratios — dropping
    analytic_speedup is a broken guard, not a skipped comparison."""
    doc = {
        "mode": "dse_fused",
        "rows": [
            {
                "name": "dse_fused",
                "us_per_call": 1.0,
                "derived": "end_to_end_speedup=2.00x;configs=100",
            }
        ],
    }
    (tmp_path / "BENCH_dse_fused.json").write_text(json.dumps(doc))
    r = _run("--root", str(tmp_path), "dse_fused")
    assert r.returncode == 2
    assert "analytic_speedup" in r.stderr
    assert "Traceback" not in r.stderr


def _dse_doc(e2e, analytic, configs):
    return {
        "mode": "dse_fused",
        "rows": [
            {
                "name": "dse_fused",
                "us_per_call": 1.0,
                "derived": (
                    f"end_to_end_speedup={e2e:.2f}x;"
                    f"analytic_speedup={analytic:.2f}x;configs={configs}"
                ),
            }
        ],
    }


def test_config_count_mismatch_skips_with_warn(tmp_path, monkeypatch, capsys):
    """A regressed-looking ratio at a DIFFERENT grid size is not
    like-for-like: skipped loudly, exit 0."""
    cd = _load_check_drift()
    (tmp_path / "BENCH_dse_fused.json").write_text(
        json.dumps(_dse_doc(1.2, 1.1, configs=1000))
    )
    monkeypatch.setattr(
        cd, "_baseline", lambda ref, name: _dse_doc(9.0, 9.0, configs=100)
    )
    rc = cd.main(["--root", str(tmp_path), "dse_fused"])
    out = capsys.readouterr().out
    assert rc == 0
    assert out.count("config count changed") == 2  # both speedup keys
    assert "FAIL" not in out


def test_equal_config_counts_still_compared(tmp_path, monkeypatch, capsys):
    """Same grid size: a real regression must still fail."""
    cd = _load_check_drift()
    (tmp_path / "BENCH_dse_fused.json").write_text(
        json.dumps(_dse_doc(1.2, 1.1, configs=100))
    )
    monkeypatch.setattr(
        cd, "_baseline", lambda ref, name: _dse_doc(9.0, 9.0, configs=100)
    )
    rc = cd.main(["--root", str(tmp_path), "dse_fused"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL" in out and "config count changed" not in out


def test_metrics_config_stamp_parsing():
    cd = _load_check_drift()
    doc = {
        "rows": [
            {
                "name": "a",
                "us_per_call": 5.0,
                "derived": "speedup=2.00x;configs=128",
            },
            {"name": "b", "us_per_call": 5.0, "derived": "speedup=3.00x"},
        ]
    }
    metrics, sizes = cd._metrics(doc, timing=True)
    assert metrics["a.speedup"] == (2.0, True)
    assert sizes == {"a.speedup": 128, "a.us_per_call": 128}
    assert "b.speedup" in metrics and "b.speedup" not in sizes


def test_fleet_replay_speedup_required(tmp_path):
    """BENCH_fabric_fleet.json without its replay_speedup headline is a
    broken guard — exit 2 naming the key, not a silent pass."""
    doc = {
        "mode": "fabric_fleet",
        "rows": [
            {"name": "fabric_fleet", "us_per_call": 1.0, "derived": "requests=1000000"}
        ],
    }
    (tmp_path / "BENCH_fabric_fleet.json").write_text(json.dumps(doc))
    r = _run("--root", str(tmp_path), "fabric_fleet")
    assert r.returncode == 2
    assert "replay_speedup" in r.stderr
    assert "Traceback" not in r.stderr


def test_fleet_replay_speedup_satisfied(tmp_path):
    doc = {
        "mode": "fabric_fleet",
        "rows": [
            {
                "name": "fabric_fleet",
                "us_per_call": 1.0,
                "derived": "replay_speedup=3.40x;configs=2;requests=1000000",
            }
        ],
    }
    (tmp_path / "BENCH_fabric_fleet.json").write_text(json.dumps(doc))
    r = _run("--root", str(tmp_path), "fabric_fleet")
    assert r.returncode == 0, r.stderr


def _faults_doc(derived="availability=0.99x;availability_nospare=0.94x;configs=6"):
    return {
        "mode": "fabric_faults",
        "rows": [
            {"name": "fabric_faults", "us_per_call": 1.0, "derived": derived}
        ],
    }


def test_fault_availability_required(tmp_path):
    """BENCH_fabric_faults.json without its availability headline is a
    broken guard — exit 2 naming the key, not a silent pass."""
    (tmp_path / "BENCH_fabric_faults.json").write_text(
        json.dumps(_faults_doc(derived="configs=6;requests=2000"))
    )
    r = _run("--root", str(tmp_path), "fabric_faults")
    assert r.returncode == 2
    assert "availability" in r.stderr
    assert "Traceback" not in r.stderr


def test_fault_availability_satisfied_and_guarded(tmp_path, monkeypatch, capsys):
    """availability parses as a higher-is-better ratio: a drop beyond the
    tolerance regresses the default (no --strict-timing) check."""
    cd = _load_check_drift()
    (tmp_path / "BENCH_fabric_faults.json").write_text(
        json.dumps(_faults_doc("availability=0.80x;configs=6"))
    )
    monkeypatch.setattr(
        cd, "_baseline", lambda ref, name: _faults_doc("availability=1.00x;configs=6")
    )
    rc = cd.main(["--root", str(tmp_path), "fabric_faults"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "FAIL" in out and "availability" in out


def test_required_bench_files_in_default_glob(tmp_path):
    """The nightly default glob must refuse to run without EVERY committed
    required bench file (dse_fused, fabric_faults, fabric_fleet) — and the
    error names the first one missing in sorted order."""
    doc = {
        "mode": "dse_fused",
        "rows": [
            {
                "name": "dse_fused",
                "us_per_call": 1.0,
                "derived": "end_to_end_speedup=2.00x;analytic_speedup=2.00x",
            }
        ],
    }
    (tmp_path / "BENCH_dse_fused.json").write_text(json.dumps(doc))
    r = _run("--root", str(tmp_path))
    assert r.returncode == 2
    assert "BENCH_fabric_faults.json" in r.stderr
    # with the faults file present the glob must next demand the fleet file
    (tmp_path / "BENCH_fabric_faults.json").write_text(json.dumps(_faults_doc()))
    r = _run("--root", str(tmp_path))
    assert r.returncode == 2
    assert "BENCH_fabric_fleet.json" in r.stderr


def test_default_glob_still_checks_repo_files():
    """Without positional modes the committed BENCH files are compared to
    HEAD — the committed numbers must never regress against themselves."""
    r = _run()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "checked" in r.stdout
