"""Allocator + simulator tests: the paper's qualitative claims must hold."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: pip install .[dev]
from hypothesis import given, settings, strategies as st

from repro.core.alloc.greedy import greedy_allocate, proportional_allocate
from repro.core.cim import allocate, run_policy


@pytest.fixture(scope="module")
def vgg(profiled):
    return profiled("vgg11", n_images=1, sample_patches=128)


# ---------------------------------------------------------------- greedy core
def test_greedy_equalizes_latency():
    base = np.array([100.0, 50.0, 10.0])
    cost = np.ones(3)
    res = greedy_allocate(base, cost, budget=20)
    # after enough replicas the latencies should be close to each other
    assert res.latency.max() / res.latency.min() < 3.0
    assert res.replicas.sum() - 3 <= 20


def test_greedy_respects_budget_and_stopping_rule():
    base = np.array([100.0, 1.0])
    cost = np.array([10.0, 1.0])
    res = greedy_allocate(base, cost, budget=9)
    # slowest unit costs 10 > 9 -> paper's rule: stop immediately.
    assert res.replicas.tolist() == [1, 1]
    assert res.leftover == 9


def test_greedy_reduces_makespan_vs_proportional_on_skew():
    """When per-unit speeds differ, latency-greedy beats weight-proportional."""
    work = np.array([100.0, 100.0, 100.0, 100.0])
    speed = np.array([1.0, 2.0, 4.0, 8.0])  # data-dependent speeds
    lat = work / speed
    cost = np.ones(4)
    g = greedy_allocate(lat, cost, budget=12)
    p = proportional_allocate(work, cost, budget=12)  # 'weight-based'
    assert g.makespan <= (lat / p.replicas).max() + 1e-9


@given(
    st.integers(2, 30).flatmap(
        lambda n: st.tuples(
            st.lists(st.floats(1, 1e4), min_size=n, max_size=n),
            st.lists(st.integers(1, 8), min_size=n, max_size=n),
            st.integers(0, 200),
        )
    )
)
@settings(max_examples=100, deadline=None)
def test_greedy_properties(args):
    lats, costs, budget = args
    base = np.asarray(lats)
    cost = np.asarray(costs, dtype=np.float64)
    res = greedy_allocate(base, cost, budget)
    # invariants: >=1 replica, budget respected, makespan <= no-dup makespan
    assert (res.replicas >= 1).all()
    assert res.spent <= budget + 1e-9
    assert res.makespan <= base.max() + 1e-9
    # exchange-optimality certificate for the greedy: no single replica can be
    # moved from unit i to unit j to reduce the makespan (with whole leftover).
    lat = base / res.replicas
    worst = lat.argmax()
    assert cost[worst] > res.leftover or np.isclose(res.spent, budget)


# ------------------------------------------------------------- CIM allocation
def test_alloc_never_exceeds_arrays(vgg):
    spec, prof = vgg
    for pol in ("baseline", "weight_based", "perf_layerwise", "blockwise"):
        for pes in (72, 100, 144, 288):
            a = allocate(spec, prof, pol, pes)
            assert a.arrays_used <= pes * 64


def test_alloc_below_minimum_raises(vgg):
    spec, prof = vgg
    with pytest.raises(ValueError):
        allocate(spec, prof, "blockwise", n_pes=10)


def test_policy_ordering_matches_paper(vgg):
    """Fig 8 ordering: blockwise >= perf_layerwise >= weight_based >= baseline."""
    spec, prof = vgg
    ips = {
        pol: run_policy(spec, prof, pol, n_pes=144).images_per_sec
        for pol in ("baseline", "weight_based", "perf_layerwise", "blockwise")
    }
    assert ips["blockwise"] >= ips["perf_layerwise"] >= ips["weight_based"]
    assert ips["weight_based"] >= ips["baseline"]  # zero-skipping only helps


def test_blockwise_speedup_is_multiple_at_scale(vgg):
    """The headline claim (7.47x ResNet18 / 3.50x VGG11 vs weight-based) —
    we assert the same phenomenon: a multi-x gap at >=2x min design size."""
    spec, prof = vgg
    bw = run_policy(spec, prof, "blockwise", n_pes=144).images_per_sec
    wb = run_policy(spec, prof, "weight_based", n_pes=144).images_per_sec
    assert bw / wb > 2.0


def test_blockwise_utilization_highest(vgg):
    """Fig 9: block-wise sustains the highest array utilization."""
    spec, prof = vgg
    util = {
        pol: run_policy(spec, prof, pol, n_pes=144).mean_utilization
        for pol in ("weight_based", "perf_layerwise", "blockwise")
    }
    assert util["blockwise"] >= util["perf_layerwise"] >= util["weight_based"]
    assert 0 < util["blockwise"] <= 1.0 + 1e-9


def test_throughput_monotone_in_design_size(vgg):
    spec, prof = vgg
    prev = 0.0
    for pes in (72, 102, 144, 204, 288):
        ips = run_policy(spec, prof, "blockwise", pes).images_per_sec
        assert ips >= prev * 0.999
        prev = ips


def test_min_design_layerwise_policies_equal(vgg):
    """Paper: 'At [minimum] PEs, all algorithms yield the same result since no
    duplication can be done.'  The layer-wise zero-skipping policies are
    exactly equal at d=1; block-wise dataflow additionally removes the
    intra-layer barrier even without duplicates, so it may be mildly faster
    (but bounded by the barrier gap, not by duplication)."""
    spec, prof = vgg
    pes = spec.min_pes(64)
    wb = run_policy(spec, prof, "weight_based", pes).images_per_sec
    pl = run_policy(spec, prof, "perf_layerwise", pes).images_per_sec
    bw = run_policy(spec, prof, "blockwise", pes).images_per_sec
    assert wb == pytest.approx(pl, rel=1e-9)
    assert pl <= bw <= 1.6 * pl
