"""``runtime/fault.py`` unit coverage on plain numpy trees: FaultInjector
schedules (fail_at budgets, slow_at stalls), checkpoint/restore-and-replay
determinism, retry exhaustion, and straggler detection — without the full
model/optimizer stack test_fault_tolerance.py drives."""

import numpy as np
import pytest

from repro.checkpoint.store import latest_step, list_steps
from repro.runtime.fault import FaultInjector, RunnerConfig, TrainRunner


def _step_fn(params, opt_state, batch):
    # deterministic in (params, step): replay after restore is bit-exact
    w = params["w"] + batch["x"]
    return {"w": w}, {"m": opt_state["m"] * 0.9 + batch["x"].sum()}, {
        "loss": float(w.sum())
    }


def _batch_fn(step):
    return {"x": np.full(4, float(step + 1))}


def _fresh():
    return {"w": np.zeros(4)}, {"m": np.float64(0.0)}


def _runner(tmp_path, **kw):
    cfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_retries_per_step=3)
    return TrainRunner(cfg, _step_fn, _batch_fn, **kw)


# ------------------------------------------------------------ FaultInjector
def test_fault_injector_fails_exactly_budget_times():
    inj = FaultInjector(fail_at={3: 2})
    inj(0)
    inj(2)  # non-listed steps pass silently
    with pytest.raises(RuntimeError, match="step 3"):
        inj(3)
    with pytest.raises(RuntimeError):
        inj(3)
    inj(3)  # budget of 2 exhausted — third visit passes
    assert inj.fail_budget[3] == 0


def test_fault_injector_slow_at_sleeps(monkeypatch):
    naps = []
    monkeypatch.setattr("time.sleep", naps.append)
    inj = FaultInjector(slow_at={2: 0.25})
    inj(1)
    inj(2)
    assert naps == [0.25]


# ----------------------------------------------------- restore-and-replay
def test_failure_replays_from_checkpoint_bit_exact(tmp_path):
    params, opt = _fresh()
    clean_p, clean_o = _runner(tmp_path / "clean").run(params, opt, 12)

    inj = FaultInjector(fail_at={7: 1})
    r = _runner(tmp_path / "faulty", fault_hook=inj)
    params, opt = _fresh()
    fault_p, fault_o = r.run(params, opt, 12)

    np.testing.assert_array_equal(fault_p["w"], clean_p["w"])
    np.testing.assert_array_equal(fault_o["m"], clean_o["m"])
    assert r.restores == 1
    # the failed attempt is recorded at the step the runner restored to
    # (checkpoint at 5), and the replay re-runs steps 5 and 6
    retried = [s for s in r.history if s.retried]
    assert [s.step for s in retried] == [5]
    steps = [s.step for s in r.history]
    assert steps.count(5) == 2 and steps.count(6) == 2 and steps.count(7) == 1


def test_retry_exhaustion_reraises(tmp_path):
    inj = FaultInjector(fail_at={2: 99})
    r = _runner(tmp_path, fault_hook=inj)
    params, opt = _fresh()
    with pytest.raises(RuntimeError, match="step 2"):
        r.run(params, opt, 10)
    # 1 initial attempt + max_retries_per_step retries, each burning budget
    assert inj.fail_budget[2] == 99 - (1 + r.cfg.max_retries_per_step)


# -------------------------------------------------- save/restore round-trip
def test_checkpoint_cadence_and_resume_round_trip(tmp_path):
    r = _runner(tmp_path)
    params, opt = _fresh()
    params, opt = r.run(params, opt, 10)
    assert list_steps(str(tmp_path)) == [5, 10]
    assert latest_step(str(tmp_path)) == 10

    # a fresh runner restores step 10 and resumes to 12...
    r2 = _runner(tmp_path)
    step, tree = r2._restore(*_fresh())
    assert step == 10
    np.testing.assert_array_equal(tree["params"]["w"], params["w"])
    np.testing.assert_array_equal(tree["opt"]["m"], opt["m"])
    p12, o12 = r2.run(tree["params"], tree["opt"], 12, start_step=step)

    # ...and lands exactly where an uninterrupted 12-step run lands
    clean_p, clean_o = _runner(tmp_path / "clean").run(*_fresh(), 12)
    np.testing.assert_array_equal(p12["w"], clean_p["w"])
    np.testing.assert_array_equal(o12["m"], clean_o["m"])


# --------------------------------------------------------------- stragglers
class _FakeClock:
    """Deterministic monotonic clock: time only moves when advanced."""

    def __init__(self, step_cost: float = 0.01):
        self.now = 0.0
        self.step_cost = step_cost

    def __call__(self):
        return self.now

    def advance(self, dt: float):
        self.now += dt

    def batch_fn(self, step):
        # every step "costs" a fixed wall time on the fake clock
        self.advance(self.step_cost)
        return _batch_fn(step)


def test_straggler_detection_fires_callback(tmp_path):
    # fully deterministic: the runner reads the fake clock, and the injected
    # stall advances it instead of sleeping — no wall-clock noise can flake
    clk = _FakeClock(step_cost=0.01)
    inj = FaultInjector(slow_at={6: 0.05}, sleep=clk.advance)
    seen = []
    cfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_retries_per_step=3)
    r = TrainRunner(
        cfg, _step_fn, clk.batch_fn, fault_hook=inj, on_straggler=seen.append,
        clock=clk,
    )
    params, opt = _fresh()
    r.run(params, opt, 10)
    assert [s.step for s in seen] == [6]
    assert seen[0].straggler and seen[0].seconds >= 0.05
    assert len(r.history) == 10


def test_straggler_warmup_suppresses_early_verdicts(tmp_path):
    # a stall inside the EWMA warm-up window (< 2 settled steps) must not
    # fire the callback, however large
    clk = _FakeClock(step_cost=0.01)
    inj = FaultInjector(slow_at={1: 10.0}, sleep=clk.advance)
    seen = []
    cfg = RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=5, max_retries_per_step=3)
    r = TrainRunner(
        cfg, _step_fn, clk.batch_fn, fault_hook=inj, on_straggler=seen.append,
        clock=clk,
    )
    params, opt = _fresh()
    r.run(params, opt, 6)
    assert seen == []
    assert not any(s.straggler for s in r.history)
